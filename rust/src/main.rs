//! `gzk` — CLI for the Random Gegenbauer Features system.
//!
//! Subcommands map 1:1 to the paper's experiments plus the serving system:
//!
//!   gzk fig1      [--degree 15]                      Figure 1
//!   gzk table1    [--n 64 --d 3 --lambda 0.5]        Table 1 (bounds + empirical)
//!   gzk table2    [--scale 0.05 --m 1024]            Table 2 (KRR, 4 datasets)
//!   gzk table3    [--scale 0.05 --m 512]             Table 3 (k-means, 6 datasets)
//!   gzk spectral  [--n 64 --d 3 --lambda 0.1]        Eq.-1 quality sweep
//!   gzk leverage  [--n 24 --d 3 --lambda 0.1]        Lemma-7 leverage-score check
//!   gzk fit       --out <dir> [--model ridge|kmeans|kpca] [--name N]
//!                 [--dataset elevation|co2|climate|protein|<table3 name>]
//!                 [--data <path>] [--chunk-rows N]
//!                 [--n 4000 --lambda 1e-2 --k 3 --rank 4 --workers 4]
//!                                                    train through the chunked data
//!                                                    pipeline and persist a model
//!                                                    artifact
//!   gzk predict   --model-dir <dir> [--name N] [--requests 500]
//!                                                    load an artifact and serve it
//!                                                    through the batcher (no refit)
//!   gzk serve     [--n 20000 --m 512 --lambda 1e-2 --requests 2000 --model-dir <dir>]
//!                 [--dataset elevation|co2|climate|protein] [--chunk-rows N]
//!                                                    end-to-end demo: one-round fit
//!                                                    -> ModelStore -> reload -> serve;
//!                                                    with an existing --model-dir it
//!                                                    skips training entirely (and then
//!                                                    rejects training flags rather than
//!                                                    silently ignoring them), rebuilding
//!                                                    its held-out eval stream from the
//!                                                    dataset recorded in the artifact
//!   gzk server    --store <dir> [--addr 127.0.0.1:7711] [--max-batch 64]
//!                 [--max-wait-us 0] [--max-queue 1024] [--poll-ms 200] [--max-conns N]
//!                 [--event-loops N] [--idle-s 300] [--allow-remote-shutdown]
//!                                                    TCP model server over a ModelStore:
//!                                                    newline-delimited JSON protocol
//!                                                    (predict/models/stats/metrics/
//!                                                    flightrec/ping/binary/shutdown),
//!                                                    multi-model routing
//!                                                    by name, manifest polled every
//!                                                    --poll-ms so a newly persisted
//!                                                    artifact serves without restart; full
//!                                                    queues answer with a retriable
//!                                                    backpressure reply. Connections are
//!                                                    multiplexed over --event-loops
//!                                                    poll(2)-driven threads (default: pool
//!                                                    width clamped to 4), so thread count
//!                                                    stays flat into the 10k-connection
//!                                                    range; a client may negotiate
//!                                                    length-prefixed binary frames
//!                                                    ({"cmd":"binary"}) and skip JSON on
//!                                                    the predict path, bit-exactly. Runs
//!                                                    until a client sends shutdown (honored
//!                                                    from loopback peers only, unless
//!                                                    --allow-remote-shutdown); connections
//!                                                    idle past --idle-s are disconnected
//!                                                    (0 disables).
//!   gzk loadgen   [--addr <host:port>] [--clients 1,8] [--requests 200] [--model N]
//!                 [--dataset <name>] [--store <dir>] [--seed 1] [--shutdown]
//!                 [--binary | --wire-compare] [--replica-sweep 1,2,4] [--traced]
//!                 [--json-out BENCH_serve.json]
//!                                                    concurrent load generator: one trial
//!                                                    per client count, rows drawn from the
//!                                                    named SyntheticSource; with --store it
//!                                                    checks every reply bit-identical to a
//!                                                    local Model::predict; emits throughput
//!                                                    + p50/p95/p99 per trial to the JSON;
//!                                                    --binary runs the trials over the
//!                                                    negotiated frame protocol instead of
//!                                                    JSON lines; --wire-compare runs BOTH
//!                                                    per client count and cross-checks
//!                                                    every reply's bits between the two;
//!                                                    --shutdown stops the server afterwards.
//!                                                    --replica-sweep spins N in-process
//!                                                    server replicas over --store behind an
//!                                                    in-process proxy per entry and records
//!                                                    a replica-scaling section (with a
//!                                                    sweep, --addr may be omitted).
//!                                                    --traced mints a u64 trace ID per
//!                                                    request (carried as the JSON "tid"
//!                                                    field or the GZF2 frame-header slot,
//!                                                    negotiated) so server-side spans
//!                                                    stitch into one distributed timeline;
//!                                                    replies stay bit-identical either way
//!   gzk worker    --addr <leader host:port> [--connect-retries 50] [--idle-s 300]
//!                                                    distributed-fit worker: registers with
//!                                                    a leader, rebuilds the broadcast spec,
//!                                                    opens its own copy of the dataset, and
//!                                                    answers shard assignments with
//!                                                    per-shard sufficient statistics
//!   gzk leader    --out <dir> [--listen 127.0.0.1:7801] [--workers 2] [--name ridge]
//!                 [--dataset elevation --n 20000 | --data PATH] [--chunk-rows 8192]
//!                 [--lambda 1e-2] [--register-timeout-s 60] [--shard-timeout-s 120]
//!                 [--verify] [--json-out PATH]
//!                                                    distributed-fit leader: waits for
//!                                                    --workers registrations, scatters
//!                                                    shard ranges, reassigns shards from
//!                                                    dead workers, merges in deterministic
//!                                                    shard order (bit-identical to the
//!                                                    in-process fit; --verify asserts it),
//!                                                    and persists the model into --out for
//!                                                    `gzk server` replicas to hot-reload
//!   gzk proxy     --replicas a:p,b:p[,...] [--listen 127.0.0.1:7810] [--probe-ms 500]
//!                 [--eject-after 3] [--attempts N] [--idle-s 300]
//!                 [--allow-remote-shutdown]
//!                                                    replica load balancer: round-robins
//!                                                    request lines across `gzk server`
//!                                                    replicas, retries backpressure
//!                                                    ("retry":true) on the next replica
//!                                                    with bounded backoff, ejects a replica
//!                                                    after --eject-after consecutive
//!                                                    transport failures and probes it back
//!                                                    in every --probe-ms; the wire shutdown
//!                                                    command (loopback-gated) fans out to
//!                                                    every replica
//!   gzk top       --targets a:p[,b:p...] [--interval-ms 2000] [--once]
//!                 [--json-out TOP.json]
//!                                                    live fleet monitor: polls the wire
//!                                                    `metrics` command on every target
//!                                                    (`gzk server` or `gzk proxy`), diffs
//!                                                    counters between polls into rates,
//!                                                    and renders a per-model table —
//!                                                    req/s, latency p50/p95/p99, queue
//!                                                    depth, admission rejects/s, open
//!                                                    connections. --once takes exactly
//!                                                    two polls one interval apart and
//!                                                    exits (for scripts/CI); --json-out
//!                                                    appends every tick to a JSON
//!                                                    document for machine consumption
//!   gzk trace-merge --inputs a.json,b.json[,...] [--out TRACE_merged.json]
//!                                                    merge per-process --trace-out files
//!                                                    (e.g. proxy + server + loadgen from
//!                                                    one traced run) into a single
//!                                                    Perfetto/Chrome timeline: each input
//!                                                    keeps its process lane, clocks are
//!                                                    normalized by midpoint alignment of
//!                                                    shared trace IDs, and spans from the
//!                                                    same request share one `args.trace`
//!                                                    ID across processes
//!   gzk info                                          artifact manifest summary
//!
//! Data flags (fit / serve):
//!
//!   --dataset N    a lazily generated synthetic source (rows are produced
//!                  per chunk — the full n x d matrix never materializes).
//!                  Regression: elevation (d=3, default), co2, climate
//!                  (d=4), protein (d=9); any Table-3 clustering name
//!                  works for kmeans/kpca.
//!   --data PATH    a file source instead: CSV (comma-separated, last
//!                  column = target, `#` comments) or the GZKBIN01
//!                  little-endian binary format. Mutually exclusive with
//!                  --dataset/--n.
//!   --chunk-rows N rows per pipeline chunk (default 8192): the working-set
//!                  bound — peak feature memory is chunk_rows x F for any n.
//!                  Doubles as the one-round protocol's shard size.
//!
//! Global flags (every subcommand):
//!
//!   --threads N    width of the process-wide exec::Pool (default: all
//!                  cores; GZK_THREADS env var is the no-CLI override).
//!                  Every parallel path — featurize, Z^T Z absorb, k-means
//!                  assignment, KPCA, the coordinator's worker wave, the
//!                  serving batcher — draws from this one pool, runs its
//!                  dense products on the register-blocked SIMD
//!                  microkernel engine (DESIGN.md §2d), and every
//!                  result is bit-identical at every width. Model
//!                  artifacts record the width — and the training dataset
//!                  name + row count — in their run metadata.
//!   --log-level L  structured-event threshold: error|warn|info|debug
//!                  (default info; GZK_LOG env var is the no-CLI
//!                  override). Diagnostics are one newline-JSON record
//!                  per event on stderr, e.g. {"ts":...,"level":"warn",
//!                  "target":"dist.leader","msg":"...","shard":7}.
//!   --log-file P   write event records to file P instead of stderr. The
//!                  sink is size-capped: when the file would exceed
//!                  --log-cap-bytes it is rotated to P.1 (one generation)
//!                  and a fresh P is started.
//!   --log-cap-bytes N
//!                  rotation threshold for --log-file (default 64 MiB).
//!   --trace-out P  collect scoped trace spans (featurize / absorb /
//!                  solve / chunk I/O / scatter / merge / shard stages,
//!                  plus per-request serve spans when requests carry a
//!                  trace ID) and write them as Chrome trace-event JSON
//!                  to P on a clean exit — load the file in
//!                  chrome://tracing or Perfetto, or stitch several
//!                  processes' files with `gzk trace-merge`. Tracing is
//!                  off (one atomic load per would-be span) unless this
//!                  flag is given.
//!   --flightrec P  arm the crash flight recorder: the last 256 event
//!                  records are kept in a fixed in-process ring and
//!                  dumped to P as one JSON document whenever an
//!                  error-level event fires; `gzk server` / `gzk proxy`
//!                  also answer the wire `flightrec` command with the
//!                  live ring.
//!
//! Observability (see DESIGN.md "Observability"): every process keeps a
//! global metrics registry (counters/gauges/latency histograms named
//! like `pipeline.rows`, `dist.leader.shards_reassigned`,
//! `proxy.replica.<addr>.ejections`); `gzk server` and `gzk proxy`
//! answer the wire `metrics` command with one consistent JSON snapshot
//! of it. Instrumentation is read-only: every fit stays bit-identical
//! with logging, metrics and tracing enabled.
//!
//! Subcommands that build a single featurizer (`fit`, `serve`, `leverage`)
//! share one flag group — `--kernel/--method/--m/--seed` plus tuning knobs —
//! parsed once by `cli::Args::feature_spec` into a `features::FeatureSpec`
//! (run `gzk serve --method fourier` to broadcast a non-Gegenbauer map).
//! The table/spectral sweeps iterate the whole method registry and reject
//! those flags rather than silently ignoring them.

use gzk::cli::Args;
use gzk::coordinator::{fit_one_round_source, fit_ridge_source, Backend, PredictionService};
use gzk::data::{pipeline, DataSource, FileSource, InterleavedSplit, SourceSlice, SyntheticSource};
use gzk::experiments::{fig1, spectral_quality, table1, table2, table3};
use gzk::features::FeatureSpec;
use gzk::krr::mse;
use gzk::model::{
    set_run_data, validate_model_name, FittedMap, KmeansModel, KpcaModel, Model, ModelKind,
    ModelStore, RidgeModel,
};
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            gzk::obs::error("cli", &format!("argument error: {e}"), &[]);
            std::process::exit(2);
        }
    };
    // the global --threads flag sizes the process-wide pool before any
    // subcommand runs compute (first sizing wins for the whole process)
    match args.threads() {
        Ok(Some(n)) => {
            let _ = gzk::exec::Pool::set_global_threads(n);
        }
        Ok(None) => {}
        Err(e) => usage_error(&e),
    }
    // the global observability flags: event threshold + sink and span
    // collection are process-wide, configured before any subcommand runs
    match args.log_level() {
        Ok(Some(level)) => gzk::obs::events::set_level(level),
        Ok(None) => {
            if let Ok(v) = std::env::var("GZK_LOG") {
                match gzk::obs::Level::parse(&v) {
                    Ok(level) => gzk::obs::events::set_level(level),
                    Err(e) => usage_error(&format!("GZK_LOG: {e}")),
                }
            }
        }
        Err(e) => usage_error(&e),
    }
    let log_cap = if args.has("log-cap-bytes") || args.get("log-cap-bytes").is_some() {
        let cap = args.get_u64("log-cap-bytes", 0);
        if cap == 0 {
            usage_error("--log-cap-bytes must be >= 1 (bytes before the log file rotates)");
        }
        Some(cap)
    } else {
        None
    };
    match args.path_flag("log-file") {
        Ok(Some(path)) => {
            let set = match log_cap {
                Some(cap) => gzk::obs::events::set_log_file_capped(path, cap),
                None => gzk::obs::events::set_log_file(path),
            };
            if let Err(e) = set {
                fatal_error(&e);
            }
        }
        Ok(None) => {
            if log_cap.is_some() {
                usage_error("--log-cap-bytes needs --log-file <path> (it caps the file sink)");
            }
        }
        Err(e) => usage_error(&e),
    }
    match args.path_flag("flightrec") {
        Ok(Some(path)) => gzk::obs::flightrec::set_dump_path(path),
        Ok(None) => {}
        Err(e) => usage_error(&e),
    }
    let trace_out = match args.path_flag("trace-out") {
        Ok(t) => t.map(str::to_string),
        Err(e) => usage_error(&e),
    };
    if trace_out.is_some() {
        gzk::obs::trace::enable();
        // the process lane label in a merged timeline ("gzk proxy",
        // "gzk server", ...) — set before any span is recorded
        gzk::obs::trace::set_process_name(&format!("gzk {}", args.subcommand));
    }
    match args.subcommand.as_str() {
        "fig1" => {
            let curves = fig1::run(args.get_usize("degree", 15));
            fig1::print(&curves);
        }
        "table1" => {
            // sweeps its own method pair and feature ladder
            reject_sweep_flags(&args, "table1", &["kernel", "method", "m"]);
            let rows = table1::run_bounds();
            table1::print_bounds(&rows);
            let n = args.get_usize("n", 64);
            let d = args.get_usize("d", 3);
            let lam = args.get_f64("lambda", 0.5);
            let emp = table1::run_empirical(n, d, lam, 0.5, args.get_u64("seed", 1));
            table1::print_empirical(&emp, 0.5);
        }
        "table2" => {
            // sweeps the whole registry with per-dataset gaussian kernels
            reject_sweep_flags(&args, "table2", &["kernel", "method"]);
            let rows = table2::run_all(
                args.get_f64("scale", 0.05),
                args.get_usize("m", 1024),
                args.get_u64("seed", 1),
            );
            table2::print(&rows);
        }
        "table3" => {
            reject_sweep_flags(&args, "table3", &["kernel", "method"]);
            let rows = table3::run_all(
                args.get_f64("scale", 0.05),
                args.get_usize("m", 512),
                args.get_u64("seed", 1),
            );
            table3::print(&rows);
        }
        "spectral" => {
            reject_sweep_flags(&args, "spectral", &["kernel", "method", "m"]);
            let (s_lambda, rows) = spectral_quality::run(
                args.get_usize("n", 64),
                args.get_usize("d", 3),
                args.get_f64("lambda", 0.1),
                args.get_u64("seed", 1),
            );
            spectral_quality::print(s_lambda, &rows);
        }
        "leverage" => leverage_demo(&args),
        "fit" => fit_cmd(&args),
        "predict" => predict_cmd(&args),
        "serve" => serve_demo(&args),
        "server" => server_cmd(&args),
        "loadgen" => loadgen_cmd(&args),
        "worker" => worker_cmd(&args),
        "leader" => leader_cmd(&args),
        "proxy" => proxy_cmd(&args),
        "top" => top_cmd(&args),
        "trace-merge" => trace_merge_cmd(&args),
        "info" => info(),
        other => {
            usage_error(&format!(
                "unknown subcommand {other:?}; see rust/src/main.rs header for usage"
            ));
        }
    }
    // error paths exit through std::process::exit and skip this — the
    // trace covers clean runs: a fit, or a server/proxy that was shut
    // down over the wire (its trace is what `gzk trace-merge` stitches)
    if let Some(path) = trace_out {
        if let Err(e) = gzk::obs::trace::write_chrome_trace(&path) {
            fatal_error(&e);
        }
        println!("wrote trace {path:?}");
    }
}

/// Usage mistakes exit(2) with an error-level event record — never a
/// panic backtrace. The `argument error: ` message prefix is part of the
/// CLI contract (cli_e2e greps it) and survives the JSON wrapping.
fn usage_error(msg: &str) -> ! {
    gzk::obs::error("cli", &format!("argument error: {msg}"), &[]);
    std::process::exit(2);
}

/// Runtime failures (I/O, corrupt artifacts, fit errors) exit(1) — distinct
/// from the exit(2) usage contract so scripts can tell them apart.
fn fatal_error(msg: &str) -> ! {
    gzk::obs::error("cli", &format!("error: {msg}"), &[]);
    std::process::exit(1);
}

/// `fatal_error` that first removes a scratch directory (serve's implicit
/// per-process store) — `process::exit` runs no destructors, so cleanup
/// must happen before the exit.
fn fatal_error_cleaning(msg: &str, scratch: Option<&std::path::Path>) -> ! {
    if let Some(dir) = scratch {
        let _ = std::fs::remove_dir_all(dir);
    }
    fatal_error(msg)
}

/// Shared latency report for the serving loops (predict/serve).
fn print_latency_summary(
    n_requests: usize,
    wall: f64,
    latencies: &mut [f64],
    metrics: &gzk::coordinator::ServeMetrics,
) {
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "served {} requests in {:.2}s  ({:.0} req/s)",
        n_requests,
        wall,
        n_requests as f64 / wall
    );
    println!(
        "latency p50 {:.2}us  p99 {:.2}us   batches {} (max size {})",
        latencies[n_requests / 2] * 1e6,
        latencies[(n_requests * 99) / 100] * 1e6,
        metrics.batches,
        metrics.max_batch_seen
    );
}

/// Parse the shared featurizer flag group, exiting with a usage error on
/// bad input (the one place CLI featurizer parsing happens).
fn parse_spec(args: &Args, default_m: usize) -> FeatureSpec {
    match args.feature_spec(default_m, 1) {
        Ok(spec) => spec,
        Err(e) => usage_error(&e),
    }
}

/// When `serve` is handed a stored model, the featurizer flag group and
/// the training knobs (which all configure *training*) would be dead
/// weight; reject them instead of silently serving a model with a
/// different configuration.
fn reject_stored_serve_flags(args: &Args, store_dir: &std::path::Path) {
    const TRAIN_FLAGS: [&str; 20] = [
        "kernel", "bandwidth", "gamma", "poly-p", "poly-c", "depth", "method", "q", "s",
        "taylor-deg", "nystrom-lambda", "m", "seed", "n", "workers", "pjrt", "lambda",
        "dataset", "data", "chunk-rows",
    ];
    for f in TRAIN_FLAGS {
        if args.get(f).is_some() || args.has(f) {
            usage_error(&format!(
                "--{f} configures training, but {store_dir:?} already holds this model and \
                 serve loads it as-is; drop the flag, use --name for a different model, or \
                 fit into a fresh --model-dir"
            ));
        }
    }
}

/// Registry-sweep subcommands construct their own spec ladders; reject the
/// single-featurizer flags instead of silently ignoring them.
fn reject_sweep_flags(args: &Args, subcommand: &str, flags: &[&str]) {
    for f in flags {
        if args.get(f).is_some() {
            usage_error(&format!(
                "--{f} does not apply to {subcommand} \
                 (it sweeps the method registry with its own kernels)"
            ));
        }
    }
}

/// Lemma-7 validator: exact ridge leverage scores over random directions
/// vs the uniform bound, plus the Theorem-9 feature-count it implies.
fn leverage_demo(args: &Args) {
    use gzk::linalg::Mat;
    use gzk::rng::Rng;
    use gzk::spectral::{lemma7_bound, leverage_score, statistical_dimension, theorem9_feature_count};

    let n = args.get_usize("n", 24);
    let d = args.get_usize("d", 3);
    let lambda = args.get_f64("lambda", 0.1);
    let spec = parse_spec(args, 512);
    let mut rng = Rng::new(spec.seed);
    let x = Mat::from_fn(n, d, |_, _| rng.normal() * 0.6);
    let table = spec
        .radial_table(d)
        .expect("leverage demo analyses the Gegenbauer method (--method gegenbauer)");

    let bound = lemma7_bound(&table, &x, lambda);
    let k = table.gzk_gram(&x);
    let s_lam = statistical_dimension(&k, lambda);
    println!("n={n} d={d} lambda={lambda}: s_lambda = {s_lam:.2}, Lemma-7 bound = {bound:.2}");
    let mut w = vec![0.0; d];
    let mut max_tau: f64 = 0.0;
    let mut sum_tau = 0.0;
    let n_mc = 200;
    for _ in 0..n_mc {
        rng.sphere(&mut w);
        let tau = leverage_score(&table, &x, &w, lambda);
        max_tau = max_tau.max(tau);
        sum_tau += tau;
    }
    println!(
        "over {n_mc} random directions: max tau = {max_tau:.3} (<= bound {bound:.3}), \
         mean tau = {:.3} (~ s_lambda {s_lam:.3})",
        sum_tau / n_mc as f64
    );
    let m9 = theorem9_feature_count(&table, &x, lambda, 0.5, 0.1, s_lam);
    println!("Theorem-9 feature count for (eps=0.5, delta=0.1): m >= {m9:.0}");
}

/// The `--chunk-rows` flag: the pipeline's working-set bound.
fn chunk_rows_flag(args: &Args) -> usize {
    let chunk = args.get_usize("chunk-rows", pipeline::DEFAULT_CHUNK_ROWS);
    if chunk == 0 {
        usage_error("--chunk-rows must be >= 1");
    }
    chunk
}

/// Open the training source from the shared `--data` / `--dataset` /
/// `--n` flag group. `--data` reads a CSV/binary file (its row count and
/// dimension come from the file, so the synthetic-geometry flags are
/// rejected rather than silently ignored); otherwise a lazily generated
/// synthetic source of `--n` rows.
fn open_source(args: &Args, default_dataset: &str, default_n: usize, seed: u64) -> Box<dyn DataSource> {
    match (args.get("data"), args.get("dataset")) {
        (Some(_), Some(_)) => {
            usage_error("--data and --dataset are mutually exclusive (a file brings its own rows)")
        }
        (Some(path), None) => {
            for f in ["n", "d"] {
                if args.get(f).is_some() {
                    usage_error(&format!(
                        "--{f} sizes the synthetic generator, but --data reads its shape \
                         from the file; drop the flag"
                    ));
                }
            }
            match FileSource::open(path) {
                Ok(s) => Box::new(s),
                Err(e) => fatal_error(&e),
            }
        }
        (None, dataset) => {
            if args.get("d").is_some() {
                // --d only sizes the generic k-means clustering mixture;
                // every named source fixes its own dimension — ignoring
                // the flag would train at a different d than the user
                // asked for
                usage_error(&format!(
                    "--d does not apply here: dataset {:?} fixes its own input dimension",
                    dataset.unwrap_or(default_dataset)
                ));
            }
            let name = dataset.unwrap_or(default_dataset);
            let n = args.get_usize("n", default_n);
            match SyntheticSource::by_name(name, n, seed) {
                Ok(s) => Box::new(s),
                Err(e) => usage_error(&e),
            }
        }
    }
}

/// Train a model through the chunked data pipeline and persist it as a
/// versioned artifact in a `ModelStore` — the "train once" half of the
/// serving lifecycle. Ridge with an oblivious method goes through the
/// coordinator's one-round protocol (workers read disjoint chunk ranges
/// of the source); everything else (k-means, KPCA, data-dependent
/// Nystrom) fits single-node through the chunked model constructors.
/// Working memory is bounded by `--chunk-rows`, never by n — a ridge fit
/// over the full climate source (n = 223,656) never allocates an n x m
/// feature matrix.
fn fit_cmd(args: &Args) {
    let kind = match ModelKind::from_name(args.get("model").unwrap_or("ridge")) {
        Ok(k) => k,
        Err(e) => usage_error(&e),
    };
    let dir = args.get("out").unwrap_or_else(|| usage_error("fit requires --out <dir>"));
    let name = args.get("name").unwrap_or(kind.name()).to_string();
    if let Err(e) = validate_model_name(&name) {
        usage_error(&e); // a bad --name is a usage mistake, not an I/O failure
    }
    let chunk_rows = chunk_rows_flag(args);
    // open (and create) the store BEFORE training: a bad --out path must
    // surface immediately, not after an hours-long streamed fit
    let store = match ModelStore::open(dir) {
        Ok(s) => s,
        Err(e) => fatal_error(&e),
    };
    let t0 = Instant::now();
    let model: Box<dyn Model> = match kind {
        ModelKind::Ridge => {
            let lambda = args.get_f64("lambda", 1e-2);
            if !lambda.is_finite() || lambda < 0.0 {
                usage_error(&format!(
                    "flag --lambda: must be a finite non-negative number, got {lambda}"
                ));
            }
            let fspec = parse_spec(args, 512);
            let seed = fspec.seed;
            let src = open_source(args, "elevation", 4000, seed);
            let spec = fspec.bind(src.dim());
            let n = src.len();
            if n < 2 {
                fatal_error(&format!("source {} has only {n} row(s)", src.name()));
            }
            // interleaved held-out split (every period-th row is test):
            // unlike a contiguous tail, this stays honest when --data is a
            // file sorted by target or time
            let period = 10.min(n);
            let train = InterleavedSplit::train(src.as_ref(), period);
            let test = InterleavedSplit::test(src.as_ref(), period);
            // the whole [0, n) range is consumed (train + held-out), so
            // serve's fresh eval rows start at n
            set_run_data(src.name(), n);
            let model = if spec.spec.method.is_oblivious() {
                let workers = args.get_usize("workers", 4);
                let backend = if args.has("pjrt") {
                    Backend::Pjrt { artifact_dir: gzk::runtime::default_artifact_dir() }
                } else {
                    Backend::Native
                };
                let (model, fit) = match fit_ridge_source(
                    &spec, &train, lambda, workers, chunk_rows, backend,
                ) {
                    Ok(v) => v,
                    Err(e) => fatal_error(&e),
                };
                println!(
                    "one-round fit: {} rows across {} workers / {} shards ({} rows/chunk)",
                    fit.stats.n, fit.n_workers, fit.n_shards, chunk_rows
                );
                model
            } else {
                match RidgeModel::fit_source(spec, &train, lambda, chunk_rows) {
                    Ok(m) => m,
                    Err(e) => fatal_error(&e),
                }
            };
            // held-out MSE, streamed chunk by chunk like the fit
            match pipeline::chunked_mse(&test, chunk_rows, |xc| model.predict_vec(xc)) {
                Ok(err) => println!("test MSE {err:.4}"),
                Err(e) => fatal_error(&e),
            }
            Box::new(model)
        }
        ModelKind::Kmeans => {
            let k = args.get_usize("k", 3);
            if k == 0 {
                usage_error("--k must be >= 1");
            }
            let fspec = parse_spec(args, 256);
            let seed = fspec.seed;
            // the kmeans default is the generic clustering mixture sized by
            // --n/--d/--k; --dataset/--data select a real geometry instead
            let src: Box<dyn DataSource> =
                if args.get("data").is_none() && args.get("dataset").is_none() {
                    let n = args.get_usize("n", 3000);
                    let d = args.get_usize("d", 8);
                    Box::new(SyntheticSource::clustering("fit", n, d, k, seed))
                } else {
                    open_source(args, "abalone", 3000, seed)
                };
            let spec = fspec.bind(src.dim());
            set_run_data(src.name(), src.len());
            let model = match KmeansModel::fit_source(spec, src.as_ref(), k, chunk_rows) {
                Ok(m) => m,
                Err(e) => fatal_error(&e),
            };
            println!(
                "k-means fit (streamed): k={k}, training objective {:.4}",
                model.objective()
            );
            Box::new(model)
        }
        ModelKind::Kpca => {
            let rank = args.get_usize("rank", 4);
            let fspec = parse_spec(args, 256);
            let seed = fspec.seed;
            let src = open_source(args, "elevation", 2000, seed);
            let spec = fspec.bind(src.dim());
            set_run_data(src.name(), src.len());
            let model = match KpcaModel::fit_source(spec, src.as_ref(), rank, chunk_rows) {
                Ok(m) => m,
                Err(e) => fatal_error(&e),
            };
            println!(
                "kpca fit (streamed): rank {rank}, top eigenvalue {:.4}",
                model.pca().eigenvalues[0]
            );
            Box::new(model)
        }
    };
    match store.save(&name, model.as_ref()) {
        Ok(path) => println!(
            "saved model {name:?} ({}) to {path:?} in {:.2}s",
            kind.name(),
            t0.elapsed().as_secs_f64()
        ),
        Err(e) => fatal_error(&e),
    }
}

/// Load a persisted model from a `ModelStore` and serve prediction
/// requests through the dynamic batcher — the "serve later" half. No
/// training happens here: the artifact is the only input.
fn predict_cmd(args: &Args) {
    let dir = args
        .get("model-dir")
        .unwrap_or_else(|| usage_error("predict requires --model-dir <dir>"));
    // reader-side open: a typo'd dir must error, not be created empty
    let store = match ModelStore::open_existing(dir) {
        Ok(s) => s,
        Err(e) => fatal_error(&e),
    };
    let name = match args.get("name") {
        Some(n) => n.to_string(),
        None => {
            let entries = store.entries().unwrap_or_else(|e| fatal_error(&e));
            match entries.len() {
                0 => usage_error(&format!("no models in {dir:?}; run `gzk fit` first")),
                1 => entries[0].name.clone(),
                _ => {
                    let names: Vec<&str> = entries.iter().map(|e| e.name.as_str()).collect();
                    usage_error(&format!(
                        "multiple models in {dir:?} ({}); pick one with --name",
                        names.join(", ")
                    ))
                }
            }
        }
    };
    let model = match store.load(&name) {
        Ok(m) => m,
        Err(e) => fatal_error(&e),
    };
    let spec = model.feature_spec().clone();
    let out_dim = model.output_dim();
    println!(
        "loaded model {name:?}: kind {}, d {}, output dim {} — serving the stored artifact, no refit",
        model.kind().name(),
        spec.d,
        out_dim
    );
    println!("spec: {}", spec.to_json());
    println!("serving pool: {} threads", gzk::exec::Pool::global().threads());

    let n_requests = args.get_usize("requests", 500);
    if n_requests == 0 {
        usage_error("--requests must be >= 1");
    }
    let svc = PredictionService::serve(model, 64, Duration::ZERO);
    let client = svc.client();
    let mut rng = gzk::rng::Rng::new(spec.spec.seed ^ 0xE7A1);
    let mut point = vec![0.0; spec.d];
    rng.sphere(&mut point);
    let _ = client.predict_vec(&point); // warm
    let mut latencies = Vec::with_capacity(n_requests);
    // first few outputs, kept as a flat matrix (one row per sampled reply)
    let mut sample = gzk::linalg::Mat::zeros(n_requests.min(3), out_dim);
    let t0 = Instant::now();
    for r in 0..n_requests {
        rng.sphere(&mut point);
        let t = Instant::now();
        let out = client.predict_vec(&point).expect("served");
        latencies.push(t.elapsed().as_secs_f64());
        if r < sample.rows() {
            sample.row_mut(r).copy_from_slice(&out);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    print_latency_summary(n_requests, wall, &mut latencies, &svc.metrics());
    for i in 0..sample.rows() {
        let cells: Vec<String> = sample.row(i).iter().map(|v| format!("{v:.4}")).collect();
        println!("sample output {i}: [{}]", cells.join(", "));
    }
}

/// End-to-end lifecycle demo: train on a lazily generated synthetic
/// source via the one-round protocol (workers read disjoint chunk ranges;
/// nothing is materialized), persist the model into a `ModelStore`,
/// **reload the artifact**, then serve batched prediction requests and
/// report latency — the serving loop never touches the in-memory fit.
/// When `--model-dir` points at a store that already holds the named
/// model, training is skipped entirely: the stored artifact is served
/// as-is, and its **recorded run metadata** (dataset name + training row
/// count) rebuilds the evaluation stream — rows past the training range
/// of the same generator — so even the stored path reports an honest
/// held-out MSE.
fn serve_demo(args: &Args) {
    let n_requests = args.get_usize("requests", 2_000);
    if n_requests == 0 {
        usage_error("--requests must be >= 1");
    }
    let name = args.get("name").unwrap_or("ridge").to_string();
    if let Err(e) = validate_model_name(&name) {
        usage_error(&e);
    }
    // Only an EXPLICIT --model-dir is reused across runs; the fallback is
    // a per-process temp store (created only after all usage validation
    // passes, removed on the way out — success or in-function failure),
    // so a plain `gzk serve` always trains — never a stale artifact from
    // an earlier PID, never an orphan directory left in temp.
    let explicit_dir = args.get("model-dir").map(PathBuf::from);
    let store_dir: PathBuf = explicit_dir.clone().unwrap_or_else(|| {
        std::env::temp_dir().join(format!("gzk-serve-{}", std::process::id()))
    });
    let scratch = if explicit_dir.is_none() { Some(store_dir.as_path()) } else { None };
    // probe read-only whether the named model is already stored (a corrupt
    // manifest in an explicit dir is a hard error, never a retrain)
    let stored = match &explicit_dir {
        Some(d) if d.is_dir() => {
            let s = ModelStore::open_existing(d).unwrap_or_else(|e| fatal_error(&e));
            s.entries().unwrap_or_else(|e| fatal_error(&e)).iter().any(|e| e.name == name)
        }
        _ => false,
    };

    println!("== gzk serve: one-round distributed KRR + model artifact + batched serving ==");
    println!("pool: {} threads", gzk::exec::Pool::global().threads());
    // (model, eval dataset name, rows already consumed by training)
    let (model, eval_dataset, train_rows): (Box<dyn Model>, String, usize) = if stored {
        // the featurizer flag group and training knobs configure TRAINING;
        // with a stored model they would be silently ignored, so reject
        // them instead (the crate's no-silent-fallback contract)
        reject_stored_serve_flags(args, &store_dir);
        let store = ModelStore::open_existing(&store_dir).unwrap_or_else(|e| fatal_error(&e));
        // the manifest names this model: a load failure now is a real
        // error (corrupt / newer-format artifact), never a reason to
        // silently retrain and clobber it
        let (m, run) = store.load_with_meta(&name).unwrap_or_else(|e| fatal_error(&e));
        println!(
            "loaded model {name:?} from {store_dir:?} — serving the stored artifact, no refit"
        );
        let (dataset, rows) = match (run.dataset, run.rows) {
            (Some(d), Some(r)) => (d, r),
            _ => fatal_error(&format!(
                "the artifact for {name:?} records no training dataset (written by an \
                 older gzk); serve cannot rebuild its eval stream — use `gzk predict \
                 --model-dir {store_dir:?} --name {name}` instead"
            )),
        };
        (m, dataset, rows)
    } else {
        // ALL usage validation happens before the store directory is
        // created, so a mistyped invocation leaves nothing behind
        let n = args.get_usize("n", 20_000);
        if n < 2 {
            usage_error("--n must be >= 2 (a training and a held-out row at minimum)");
        }
        let n_workers = args.get_usize("workers", 4);
        let chunk_rows = chunk_rows_flag(args);
        let lambda = args.get_f64("lambda", 1e-2);
        if !lambda.is_finite() || lambda < 0.0 {
            usage_error(&format!(
                "flag --lambda: must be a finite non-negative number, got {lambda}"
            ));
        }
        if args.get("data").is_some() {
            usage_error(
                "serve's demo trains on a regenerable synthetic source (--dataset); \
                 fit file data with `gzk fit --data ...` and serve it with `gzk predict`",
            );
        }
        let fspec = parse_spec(args, 512);
        if !fspec.method.is_oblivious() {
            usage_error(&format!(
                "--method {} is data-dependent and cannot be broadcast by the \
                 one-round protocol; pick an oblivious method",
                fspec.method.name()
            ));
        }
        let seed = fspec.seed;
        let dataset = args.get("dataset").unwrap_or("elevation");
        let src = match SyntheticSource::by_name(dataset, n, seed) {
            Ok(s) => s,
            Err(e) => usage_error(&e),
        };
        let spec = fspec.bind(src.dim());
        println!("spec: {}", spec.to_json());
        let store = match ModelStore::open(&store_dir) {
            Ok(s) => s,
            Err(e) => fatal_error(&e),
        };
        let n_tr = n - (n / 10).max(1);
        let train = SourceSlice::new(&src, 0, n_tr);
        set_run_data(src.name(), n_tr);
        let backend = if args.has("pjrt") {
            Backend::Pjrt { artifact_dir: gzk::runtime::default_artifact_dir() }
        } else {
            Backend::Native
        };
        let t0 = Instant::now();
        let (model, fit) = fit_ridge_source(&spec, &train, lambda, n_workers, chunk_rows, backend)
            .unwrap_or_else(|e| fatal_error_cleaning(&e, scratch));
        println!(
            "trained on {} rows across {} workers / {} shards in {:.2}s (featurize CPU {:.2}s)",
            fit.stats.n,
            fit.n_workers,
            fit.n_shards,
            t0.elapsed().as_secs_f64(),
            fit.featurize_secs_total
        );
        let path = match store.save(&name, &model) {
            Ok(p) => p,
            Err(e) => fatal_error_cleaning(&e, scratch),
        };
        println!("saved model {name:?} to {path:?}");
        // the serving path always goes through the artifact store
        let reloaded = store
            .load(&name)
            .unwrap_or_else(|e| fatal_error_cleaning(&e, scratch));
        (reloaded, dataset.to_string(), n_tr)
    };

    let spec = model.feature_spec().clone();
    if model.kind() != ModelKind::Ridge {
        usage_error(&format!(
            "serve's demo scores regression output, but the stored model \
             {name:?} is {}; serve it with `gzk predict --model-dir ... --name {name}`",
            model.kind().name()
        ));
    }
    let seed = spec.spec.seed;
    // The eval stream comes from the SAME generator the model was trained
    // on (recorded in the artifact's run metadata), at row indices the
    // training range never touched — the synthetic sources are infinite
    // streams, so the held-out MSE is honest on both paths. A model
    // trained on data serve cannot regenerate (a file source) errors
    // above with the recorded name.
    let n_eval = 1024usize;
    let eval_src = match SyntheticSource::by_name(&eval_dataset, train_rows + n_eval, seed) {
        Ok(s) => s,
        Err(_) => fatal_error_cleaning(
            &format!(
                "stored model {name:?} was trained on {eval_dataset:?}, which serve cannot \
                 regenerate; use `gzk predict --model-dir ... --name {name}` instead"
            ),
            scratch,
        ),
    };
    if eval_src.dim() != spec.d {
        fatal_error_cleaning(
            &format!(
                "recorded dataset {eval_dataset:?} has d = {} but the stored model expects \
                 d = {} — artifact metadata mismatch",
                eval_src.dim(),
                spec.d
            ),
            scratch,
        );
    }
    let (x_te, y_te) = eval_src
        .read_range(train_rows, train_rows + n_eval)
        .unwrap_or_else(|e| fatal_error_cleaning(&e, scratch));
    println!("eval stream: {n_eval} held-out {eval_dataset} rows (from row {train_rows})");

    let svc = PredictionService::serve(model, 64, Duration::ZERO);
    let client = svc.client();
    // warm
    let _ = client.predict(x_te.row(0));
    let mut latencies = Vec::with_capacity(n_requests);
    let mut preds = Vec::with_capacity(n_requests);
    let t1 = Instant::now();
    for r in 0..n_requests {
        let i = r % x_te.rows();
        let t = Instant::now();
        preds.push(client.predict(x_te.row(i)).expect("served"));
        latencies.push(t.elapsed().as_secs_f64());
    }
    let wall = t1.elapsed().as_secs_f64();
    print_latency_summary(n_requests, wall, &mut latencies, &svc.metrics());
    let truth: Vec<f64> = (0..n_requests).map(|r| y_te[r % y_te.len()]).collect();
    println!("held-out MSE over served predictions: {:.4}", mse(&preds, &truth));
    // the implicit per-process store was only a vehicle for the
    // persist→reload round trip; don't leave orphans in temp
    if let Some(dir) = scratch {
        let _ = std::fs::remove_dir_all(dir);
    }
}

/// The L4 network front-end: serve every model in a `ModelStore` over
/// TCP (newline-delimited JSON), hot-reloading the store manifest so
/// `gzk fit --out <store>` against a live server is the whole deployment
/// story. Runs until a client sends the `shutdown` command.
fn server_cmd(args: &Args) {
    let dir = args.get("store").unwrap_or_else(|| {
        usage_error("server requires --store <dir> (a ModelStore written by `gzk fit`)")
    });
    let addr = args.get("addr").unwrap_or("127.0.0.1:7711");
    let max_batch = args.get_usize("max-batch", 64);
    if max_batch == 0 {
        usage_error("--max-batch must be >= 1");
    }
    let max_queue = args.get_usize("max-queue", 1024);
    if max_queue == 0 {
        usage_error("--max-queue must be >= 1");
    }
    let poll_ms = args.get_usize("poll-ms", 200);
    if poll_ms == 0 {
        usage_error("--poll-ms must be >= 1");
    }
    let max_conns = args.get_usize("max-conns", 0); // 0 = pool policy
    let event_loops = args.get_usize("event-loops", 0); // 0 = pool policy
    let cfg = gzk::server::ServerConfig {
        max_batch,
        max_wait: Duration::from_micros(args.get_usize("max-wait-us", 0) as u64),
        max_queue,
        poll: Duration::from_millis(poll_ms as u64),
        max_conns,
        idle_timeout: Duration::from_secs(args.get_usize("idle-s", 300) as u64),
        allow_remote_shutdown: args.has("allow-remote-shutdown"),
        event_loops,
    };
    let server = match gzk::server::Server::start(dir, addr, cfg) {
        Ok(s) => s,
        Err(e) => fatal_error(&e),
    };
    let n_loops = if event_loops > 0 {
        event_loops
    } else {
        gzk::exec::Pool::global().threads().clamp(1, 4)
    };
    println!(
        "gzk server listening on {} — models: {} (store {dir:?}, poll {poll_ms}ms, \
         pool {} threads, {n_loops} event loop{})",
        server.local_addr(),
        server.model_names().join(", "),
        gzk::exec::Pool::global().threads(),
        if n_loops == 1 { "" } else { "s" }
    );
    println!(
        r#"protocol: one JSON object per line, e.g. {{"cmd":"predict","model":"ridge","x":[...]}}; cmds: predict, models, stats, metrics, flightrec, ping, binary, shutdown"#
    );
    let final_stats = server.wait();
    println!("gzk server: shut down cleanly");
    println!("final stats: {final_stats}");
}

/// Concurrent load generator against a running `gzk server`: one trial
/// per `--clients` entry, every reply optionally verified bit-identical
/// to a local `Model::predict` (via `--store`), results written to
/// `BENCH_serve.json`.
fn loadgen_cmd(args: &Args) {
    let replica_sweep = match args.get_usize_list("replica-sweep", &[]) {
        Ok(s) => s,
        Err(e) => usage_error(&e),
    };
    let addr = args.get("addr");
    if addr.is_none() && replica_sweep.is_empty() {
        usage_error(
            "loadgen requires --addr <host:port> (a running `gzk server`), \
             --replica-sweep <counts> (self-hosted replica scaling over --store), or both",
        );
    }
    let clients = match args.get_usize_list("clients", &[1, 8]) {
        Ok(c) => c,
        Err(e) => usage_error(&e),
    };
    let requests = args.get_usize("requests", 200);
    if requests == 0 {
        usage_error("--requests must be >= 1");
    }
    let wire = match (args.has("binary"), args.has("wire-compare")) {
        (true, true) => usage_error("--binary and --wire-compare are mutually exclusive"),
        (true, false) => gzk::server::WireMode::Binary,
        (false, true) => gzk::server::WireMode::Compare,
        (false, false) => gzk::server::WireMode::Json,
    };
    let cfg = gzk::server::LoadgenConfig {
        addr: addr.unwrap_or("").to_string(),
        clients,
        requests_per_client: requests,
        dataset: args.get("dataset").map(str::to_string),
        model: args.get("model").map(str::to_string),
        store: args.get("store").map(PathBuf::from),
        seed: args.get_u64("seed", 1),
        send_shutdown: args.has("shutdown"),
        replica_sweep,
        wire,
        traced: args.has("traced"),
    };
    let report = match gzk::server::loadgen::run(&cfg) {
        Ok(r) => r,
        Err(e) => fatal_error(&e),
    };
    println!(
        "loadgen against {} — model {:?}, dataset {}, {} requests/client, bit-identity {}",
        if report.addr.is_empty() { "<in-process replica sweep>" } else { &report.addr },
        report.model,
        report.dataset,
        report.requests_per_client,
        if report.verified {
            "VERIFIED against the local artifact"
        } else {
            "not checked (pass --store <dir>)"
        }
    );
    if !report.trials.is_empty() {
        let mut table = gzk::bench::Table::new(vec![
            "clients", "wire", "req/s", "p50 us", "p95 us", "p99 us", "retries", "mismatches",
        ]);
        for t in &report.trials {
            // in compare mode a binary trial's row folds the cross-check
            // against its JSON twin into the mismatch column
            table.row(vec![
                format!("{}", t.clients),
                t.wire.to_string(),
                format!("{:.0}", t.throughput_rps),
                format!("{:.1}", t.p50_us),
                format!("{:.1}", t.p95_us),
                format!("{:.1}", t.p99_us),
                format!("{}", t.retries),
                format!("{}", t.mismatches + t.cross_mismatches),
            ]);
        }
        table.print();
    }
    if !report.replica_trials.is_empty() {
        println!(
            "replica-scaling sweep ({} clients through an in-process proxy):",
            report.replica_trials.first().map(|r| r.trial.clients).unwrap_or(0)
        );
        let mut table = gzk::bench::Table::new(vec![
            "replicas", "req/s", "p50 us", "p95 us", "p99 us", "retries", "mismatches",
        ]);
        for r in &report.replica_trials {
            table.row(vec![
                format!("{}", r.replicas),
                format!("{:.0}", r.trial.throughput_rps),
                format!("{:.1}", r.trial.p50_us),
                format!("{:.1}", r.trial.p95_us),
                format!("{:.1}", r.trial.p99_us),
                format!("{}", r.trial.retries),
                format!("{}", r.trial.mismatches),
            ]);
        }
        table.print();
    }
    // one stats line per client count (compare mode has two trials per
    // count but still one stats capture); sweep-only runs have no direct
    // captures to label
    if !report.trials.is_empty() {
        for (n, stats) in cfg.clients.iter().zip(&report.server_stats) {
            println!("server stats after {n} clients: {stats}");
        }
    }
    if let Some(n) = report.admission_rejected_total {
        println!(
            "admission cross-check: registry rejected_total = {n}, consistent with the \
             stats reply"
        );
    }
    let json_path = PathBuf::from(args.get("json-out").unwrap_or("BENCH_serve.json"));
    match report.write_json(&json_path) {
        Ok(()) => println!("wrote {json_path:?}"),
        Err(e) => fatal_error(&e),
    }
    if cfg.send_shutdown {
        println!("sent shutdown; the server is stopping");
    }
    if report.mismatches() > 0 {
        fatal_error(&format!(
            "{} replies were NOT bit-identical to the local model",
            report.mismatches()
        ));
    }
}

/// One `gzk worker` process: connect to the leader, serve shard
/// assignments until the fleet drains. Exits 0 on a clean drain, 1 on
/// any protocol or I/O failure (the leader reassigns the shard either
/// way).
fn worker_cmd(args: &Args) {
    let addr = args
        .get("addr")
        .unwrap_or_else(|| usage_error("worker requires --addr <leader host:port>"));
    let connect_attempts = args.get_usize("connect-retries", 50);
    if connect_attempts == 0 {
        usage_error("--connect-retries must be >= 1");
    }
    let idle_s = args.get_usize("idle-s", 300);
    if idle_s == 0 {
        usage_error("--idle-s must be >= 1 (the worker needs a liveness deadline on the leader)");
    }
    let opts = gzk::dist::WorkerOptions {
        connect_attempts,
        idle_timeout: Duration::from_secs(idle_s as u64),
        ..gzk::dist::WorkerOptions::default()
    };
    println!("gzk worker connecting to leader {addr}");
    match gzk::dist::run_worker(addr, &opts) {
        Ok(r) => println!(
            "worker {} done: {} shard(s), {} rows, featurize CPU {:.2}s",
            r.worker_id, r.shards, r.rows, r.featurize_secs
        ),
        Err(e) => fatal_error(&e),
    }
}

/// The `gzk leader` process: scatter the one-round fit across a worker
/// fleet over TCP, merge bit-identically to the in-process fit
/// (`--verify` asserts exactly that), and persist the model into a
/// ModelStore that `gzk server` replicas hot-reload.
fn leader_cmd(args: &Args) {
    let dir = args.get("out").unwrap_or_else(|| {
        usage_error("leader requires --out <dir> (the ModelStore the fitted model lands in)")
    });
    let name = args.get("name").unwrap_or("ridge").to_string();
    if let Err(e) = validate_model_name(&name) {
        usage_error(&e);
    }
    let n_workers = args.get_usize("workers", 2);
    if n_workers == 0 {
        usage_error("--workers must be >= 1");
    }
    let chunk_rows = chunk_rows_flag(args);
    let lambda = args.get_f64("lambda", 1e-2);
    if !lambda.is_finite() || lambda < 0.0 {
        usage_error(&format!("flag --lambda: must be a finite non-negative number, got {lambda}"));
    }
    let fspec = parse_spec(args, 512);
    if !fspec.method.is_oblivious() {
        usage_error(&format!(
            "--method {} is data-dependent and cannot be broadcast by the \
             one-round protocol; pick an oblivious method",
            fspec.method.name()
        ));
    }
    // the job's dataset descriptor: a *name* every worker resolves against
    // its own filesystem / generator — the leader never ships rows
    let data = match (args.get("data"), args.get("dataset")) {
        (Some(_), Some(_)) => {
            usage_error("--data and --dataset are mutually exclusive (a file brings its own rows)")
        }
        (Some(path), None) => {
            if args.get("n").is_some() {
                usage_error(
                    "--n sizes the synthetic generator, but --data reads its shape from \
                     the file; drop the flag",
                );
            }
            let src = FileSource::open(path).unwrap_or_else(|e| fatal_error(&e));
            gzk::dist::DataSpec { name: format!("file:{path}"), rows: src.len(), seed: fspec.seed }
        }
        (None, dataset) => {
            if args.get("d").is_some() {
                usage_error(&format!(
                    "--d does not apply here: dataset {:?} fixes its own input dimension",
                    dataset.unwrap_or("elevation")
                ));
            }
            gzk::dist::DataSpec {
                name: dataset.unwrap_or("elevation").to_string(),
                rows: args.get_usize("n", 20_000),
                seed: fspec.seed,
            }
        }
    };
    // open the leader's own copy up front: a bad descriptor must fail
    // before the port binds, not after the fleet registered
    let src = data.open().unwrap_or_else(|e| fatal_error(&e));
    let spec = fspec.bind(src.dim());
    let store = match ModelStore::open(dir) {
        Ok(s) => s,
        Err(e) => fatal_error(&e),
    };
    let cfg = gzk::dist::LeaderConfig {
        n_workers,
        rows_per_shard: chunk_rows,
        register_timeout: Duration::from_secs(args.get_usize("register-timeout-s", 60) as u64),
        shard_timeout: Duration::from_secs(args.get_usize("shard-timeout-s", 120) as u64),
    };
    let listen = args.get("listen").unwrap_or("127.0.0.1:7801");
    let leader = match gzk::dist::DistLeader::bind(listen, cfg) {
        Ok(l) => l,
        Err(e) => fatal_error(&e),
    };
    match leader.local_addr() {
        Ok(a) => println!(
            "gzk leader listening on {a} — waiting for {n_workers} worker(s) \
             (`gzk worker --addr {a}`)"
        ),
        Err(e) => fatal_error(&e),
    }
    println!("spec: {}", spec.to_json());
    let fit = match leader.run(&spec, &data, lambda) {
        Ok(f) => f,
        Err(e) => fatal_error(&e),
    };
    println!(
        "distributed fit: {} rows / {} shards across {} worker(s) in {:.2}s \
         (featurize CPU {:.2}s; {} reassigned, {} recovered locally, {} dead workers)",
        fit.stats.n,
        fit.n_shards,
        fit.n_workers,
        fit.wall_secs,
        fit.featurize_secs_total,
        fit.reassigned_shards,
        fit.recovered_shards,
        fit.dead_workers
    );

    // --verify: rerun the fit in-process over the same source and demand
    // bit-identical weights — the distributed tier's correctness claim,
    // checked end to end (this is what the CI smoke job asserts)
    let verified = if args.has("verify") {
        let local = fit_one_round_source(
            &spec,
            src.as_ref(),
            lambda,
            n_workers,
            chunk_rows,
            Backend::Native,
        )
        .unwrap_or_else(|e| fatal_error(&e));
        let same = fit.model.weights.len() == local.model.weights.len()
            && fit
                .model
                .weights
                .iter()
                .zip(&local.model.weights)
                .all(|(a, b)| a.to_bits() == b.to_bits());
        if !same {
            fatal_error("distributed weights are NOT bit-identical to the in-process fit");
        }
        println!(
            "verified: distributed weights bit-identical to the in-process fit ({} floats)",
            fit.model.weights.len()
        );
        true
    } else {
        false
    };

    set_run_data(&data.name, data.rows);
    let map = FittedMap::rebuild(spec.clone(), None).unwrap_or_else(|e| fatal_error(&e));
    let model = RidgeModel::from_parts(map, fit.model.clone());
    match store.save(&name, &model) {
        Ok(path) => println!("saved model {name:?} to {path:?}"),
        Err(e) => fatal_error(&e),
    }
    if let Some(json_path) = args.get("json-out") {
        let text = format!(
            concat!(
                r#"{{"format":1,"bench":"distfit","mode":"leader","dataset":{},"rows":{},"#,
                r#""workers":{},"shards":{},"wall_secs":{:.4},"featurize_secs_total":{:.4},"#,
                r#""reassigned_shards":{},"recovered_shards":{},"dead_workers":{},"verified":{}}}"#
            ),
            gzk::model::artifact::json_string(&data.name),
            fit.stats.n,
            fit.n_workers,
            fit.n_shards,
            fit.wall_secs,
            fit.featurize_secs_total,
            fit.reassigned_shards,
            fit.recovered_shards,
            fit.dead_workers,
            verified,
        );
        let path = PathBuf::from(json_path);
        match std::fs::write(&path, text) {
            Ok(()) => println!("wrote {path:?}"),
            Err(e) => fatal_error(&format!("write {path:?}: {e}")),
        }
    }
}

/// The `gzk proxy` process: a round-robin load balancer over `gzk
/// server` replicas with retry-on-backpressure and eject-and-probe
/// health. Runs until a (loopback) client sends the wire shutdown
/// command, which fans out to every replica first.
fn proxy_cmd(args: &Args) {
    let replicas = match args.get_addr_list("replicas") {
        Ok(r) => r,
        Err(e) => usage_error(&e),
    };
    if replicas.is_empty() {
        usage_error("proxy requires --replicas <host:port,...> (running `gzk server` replicas)");
    }
    let listen = args.get("listen").unwrap_or("127.0.0.1:7810");
    let probe_ms = args.get_usize("probe-ms", 500);
    if probe_ms == 0 {
        usage_error("--probe-ms must be >= 1");
    }
    let eject_after = args.get_usize("eject-after", 3);
    if eject_after == 0 {
        usage_error("--eject-after must be >= 1");
    }
    let idle_s = args.get_usize("idle-s", 300);
    let cfg = gzk::dist::ProxyConfig {
        eject_after: eject_after as u32,
        probe_interval: Duration::from_millis(probe_ms as u64),
        attempts: args.get_usize("attempts", 0),
        idle_timeout: if idle_s == 0 { None } else { Some(Duration::from_secs(idle_s as u64)) },
        allow_remote_shutdown: args.has("allow-remote-shutdown"),
    };
    let proxy = match gzk::dist::Proxy::start(listen, replicas.clone(), cfg) {
        Ok(p) => p,
        Err(e) => fatal_error(&e),
    };
    println!(
        "gzk proxy listening on {} — {} replica(s): {}",
        proxy.local_addr(),
        replicas.len(),
        replicas.join(", ")
    );
    println!("forwarding the serving protocol; shutdown (loopback) fans out to every replica");
    let summary = proxy.wait();
    println!("gzk proxy: shut down cleanly ({summary})");
}

/// The `gzk top` live fleet monitor: poll the wire `metrics` command on
/// every `--targets` address, diff counters between polls into rates,
/// and render a per-model table (plus `--json-out` for scripts).
fn top_cmd(args: &Args) {
    let targets = match args.get_addr_list("targets") {
        Ok(t) => t,
        Err(e) => usage_error(&e),
    };
    if targets.is_empty() {
        usage_error(
            "top requires --targets <host:port,...> (running `gzk server` / `gzk proxy` \
             addresses)",
        );
    }
    let interval_ms = args.get_usize("interval-ms", 2000);
    if interval_ms == 0 {
        usage_error("--interval-ms must be >= 1");
    }
    let cfg = gzk::server::top::TopConfig {
        targets,
        interval: Duration::from_millis(interval_ms as u64),
        once: args.has("once"),
        json_out: args.get("json-out").map(PathBuf::from),
    };
    let mut print_tick = |s: &str| print!("{s}");
    if let Err(e) = gzk::server::top::run_top(&cfg, &mut print_tick) {
        fatal_error(&e);
    }
}

/// The `gzk trace-merge` stitcher: merge several processes' `--trace-out`
/// files into one Perfetto/Chrome timeline (clocks normalized via shared
/// trace IDs — see DESIGN.md §3e).
fn trace_merge_cmd(args: &Args) {
    let inputs = match args.get_path_list("inputs") {
        Ok(i) => i,
        Err(e) => usage_error(&e),
    };
    if inputs.len() < 2 {
        usage_error(
            "trace-merge requires --inputs <a.json,b.json,...> — at least two --trace-out \
             files to stitch",
        );
    }
    let out = PathBuf::from(args.get("out").unwrap_or("TRACE_merged.json"));
    let doc = match gzk::obs::merge::merge_traces(&inputs) {
        Ok(d) => d,
        Err(e) => fatal_error(&e),
    };
    match std::fs::write(&out, &doc) {
        Ok(()) => println!("wrote merged trace {out:?} ({} input files)", inputs.len()),
        Err(e) => fatal_error(&format!("write {out:?}: {e}")),
    }
}

fn info() {
    let dir = gzk::runtime::default_artifact_dir();
    println!("artifact dir: {dir:?}");
    match gzk::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("{} featurize artifacts, {} krr_solve artifacts", m.featurize.len(), m.krr_solve.len());
            for f in &m.featurize {
                println!(
                    "  featurize {} d={} q={} s={} tile {}x{}",
                    f.family, f.d, f.q, f.s, f.block_b, f.block_m
                );
            }
            for k in &m.krr_solve {
                println!("  krr_solve F={}", k.f);
            }
        }
        Err(e) => println!("no manifest: {e} (run `make artifacts`)"),
    }
}
