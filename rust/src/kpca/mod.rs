//! Kernel principal component analysis through random features — the
//! second downstream application licensed by Theorem 10 (projection-cost
//! preservation): the top-r principal subspace of the feature matrix Z is
//! a near-optimal rank-r approximation of the kernel's eigenspace.
//!
//! PCA is done on the (F x F) feature covariance — O(n F^2 + F^3) instead
//! of the exact kernel method's O(n^3).

use crate::exec::Pool;
use crate::linalg::{sym_eigen, Mat};

/// Fitted kernel-PCA model: mean in feature space + top-r directions.
pub struct KernelPca {
    mean: Vec<f64>,
    /// (F x r) principal directions, columns orthonormal
    components: Mat,
    /// explained variance per component (descending)
    pub eigenvalues: Vec<f64>,
}

impl KernelPca {
    /// Fit on a featurized dataset Z (n x F), keeping r components; the
    /// O(n F^2) covariance assembly draws from the global pool.
    pub fn fit(z: &Mat, r: usize) -> KernelPca {
        Self::fit_with(z, r, &Pool::global())
    }

    /// [`fit`](KernelPca::fit) on an explicit pool (bit-identical to the
    /// serial fit at every thread count — the parallel SYRK fixes its
    /// reduction order).
    pub fn fit_with(z: &Mat, r: usize, pool: &Pool) -> KernelPca {
        let (n, f) = (z.rows(), z.cols());
        assert!(r <= f && n > 1);
        // column means
        let mut mean = vec![0.0; f];
        for i in 0..n {
            for (m, &v) in mean.iter_mut().zip(z.row(i)) {
                *m += v;
            }
        }
        for m in mean.iter_mut() {
            *m /= n as f64;
        }
        // covariance C = (Zc^T Zc) / n via syrk on centered rows
        let mut zc = z.clone();
        for i in 0..n {
            for (v, &m) in zc.row_mut(i).iter_mut().zip(&mean) {
                *v -= m;
            }
        }
        let mut cov = Mat::zeros(f, f);
        zc.syrk_into_p(&mut cov, pool);
        cov.symmetrize_from_upper();
        cov.scale(1.0 / n as f64);
        Self::from_covariance(mean, &cov, r)
    }

    /// Finish a fit from the feature-space mean and the (F x F) feature
    /// covariance: eigendecompose and keep the top-`r` directions. Shared
    /// tail of [`fit_with`](KernelPca::fit_with) and the streaming
    /// two-pass fit of `data::pipeline` — identical covariance in,
    /// bit-identical model out.
    pub fn from_covariance(mean: Vec<f64>, cov: &Mat, r: usize) -> KernelPca {
        let f = mean.len();
        assert_eq!((cov.rows(), cov.cols()), (f, f), "covariance/mean dim mismatch");
        assert!(r <= f, "rank {r} exceeds feature dimension {f}");
        let (evals, evecs) = sym_eigen(cov);
        let mut components = Mat::zeros(f, r);
        for j in 0..r {
            for i in 0..f {
                components[(i, j)] = evecs[(i, j)];
            }
        }
        KernelPca { mean, components, eigenvalues: evals[..r].to_vec() }
    }

    /// Rebuild a fitted model from persisted parts (the model artifact
    /// codec); inverse of reading [`mean`](KernelPca::mean) /
    /// [`components`](KernelPca::components) / `eigenvalues`.
    pub fn from_parts(mean: Vec<f64>, components: Mat, eigenvalues: Vec<f64>) -> KernelPca {
        assert_eq!(components.cols(), eigenvalues.len(), "rank/eigenvalue mismatch");
        assert_eq!(mean.len(), components.rows(), "mean/components dim mismatch");
        KernelPca { mean, components, eigenvalues }
    }

    /// Feature-space mean subtracted before projection (length F).
    pub fn mean(&self) -> &[f64] {
        &self.mean
    }

    /// (F x r) principal directions, columns orthonormal.
    pub fn components(&self) -> &Mat {
        &self.components
    }

    pub fn rank(&self) -> usize {
        self.components.cols()
    }

    /// Project featurized points onto the principal subspace: (n x r).
    /// Row parallelism comes from the global pool (clamped for tiny
    /// batches); bit-identical to a serial projection.
    pub fn transform(&self, z: &Mat) -> Mat {
        self.transform_with(z, &Pool::for_rows(z.rows()))
    }

    /// [`transform`](KernelPca::transform) on an explicit pool.
    pub fn transform_with(&self, z: &Mat, pool: &Pool) -> Mat {
        let mut zc = z.clone();
        for i in 0..z.rows() {
            for (v, &m) in zc.row_mut(i).iter_mut().zip(&self.mean) {
                *v -= m;
            }
        }
        zc.matmul_p(&self.components, pool)
    }

    /// Reconstruction error: mean squared distance between centered rows
    /// and their projection onto the subspace. Equals the mean of the
    /// discarded eigenvalue mass on the training set.
    pub fn reconstruction_error(&self, z: &Mat) -> f64 {
        let proj = self.transform(z); // (n x r)
        let mut total = 0.0;
        for i in 0..z.rows() {
            let zr = z.row(i);
            let centered_sq: f64 = zr
                .iter()
                .zip(&self.mean)
                .map(|(&v, &m)| (v - m) * (v - m))
                .sum();
            let proj_sq: f64 = proj.row(i).iter().map(|v| v * v).sum();
            total += centered_sq - proj_sq;
        }
        total / z.rows() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::{FeatureSpec, Featurizer, KernelSpec, Method};
    use crate::rng::Rng;

    #[test]
    fn recovers_planted_low_rank_structure() {
        // data concentrated on a 2-D subspace of feature space
        let mut rng = Rng::new(180);
        let n = 200;
        let mut z = Mat::zeros(n, 10);
        for i in 0..n {
            let (a, b) = (rng.normal() * 3.0, rng.normal());
            for j in 0..10 {
                z[(i, j)] = a * (j as f64 / 10.0) + b * ((j % 2) as f64) + 0.01 * rng.normal();
            }
        }
        let pca = KernelPca::fit(&z, 2);
        assert!(pca.eigenvalues[0] >= pca.eigenvalues[1]);
        let err = pca.reconstruction_error(&z);
        assert!(err < 0.01, "{err}");
    }

    #[test]
    fn transform_shapes_and_orthogonality() {
        let mut rng = Rng::new(181);
        let z = Mat::from_fn(50, 8, |_, _| rng.normal());
        let pca = KernelPca::fit(&z, 3);
        let t = pca.transform(&z);
        assert_eq!((t.rows(), t.cols()), (50, 3));
        // components orthonormal
        let ctc = pca.components.matmul_tn(&pca.components);
        assert!(ctc.max_abs_diff(&Mat::eye(3)) < 1e-8);
    }

    #[test]
    fn error_decreases_with_rank() {
        let mut rng = Rng::new(182);
        let z = Mat::from_fn(80, 12, |_, _| rng.normal());
        let e2 = KernelPca::fit(&z, 2).reconstruction_error(&z);
        let e6 = KernelPca::fit(&z, 6).reconstruction_error(&z);
        let e12 = KernelPca::fit(&z, 12).reconstruction_error(&z);
        assert!(e6 < e2);
        assert!(e12 < 1e-8, "{e12}");
    }

    #[test]
    fn from_parts_reproduces_fitted_model() {
        let mut rng = Rng::new(184);
        let z = Mat::from_fn(40, 6, |_, _| rng.normal());
        let pca = KernelPca::fit(&z, 3);
        let rebuilt = KernelPca::from_parts(
            pca.mean().to_vec(),
            pca.components().clone(),
            pca.eigenvalues.clone(),
        );
        assert_eq!(pca.transform(&z), rebuilt.transform(&z));
        assert_eq!(pca.rank(), rebuilt.rank());
    }

    #[test]
    fn kernel_pca_through_gegenbauer_features() {
        // clustered data on S^2 -> kernel PCA separates the clusters in
        // a low-dimensional embedding
        let mut rng = Rng::new(183);
        let n = 120;
        let mut x = Mat::zeros(n, 3);
        let mut c0 = vec![0.0; 3];
        let mut c1 = vec![0.0; 3];
        rng.sphere(&mut c0);
        rng.sphere(&mut c1);
        for i in 0..n {
            let c = if i % 2 == 0 { &c0 } else { &c1 };
            let row = x.row_mut(i);
            for (j, r) in row.iter_mut().enumerate() {
                *r = c[j] + 0.2 * rng.normal();
            }
            let norm: f64 = row.iter().map(|v| v * v).sum::<f64>().sqrt();
            for r in row.iter_mut() {
                *r /= norm;
            }
        }
        let spec = FeatureSpec::new(
            KernelSpec::Gaussian { bandwidth: 1.0 },
            Method::Gegenbauer { q: 8, s: 2 },
            512,
            184,
        );
        let z = spec.build(3).featurize(&x);
        let pca = KernelPca::fit(&z, 2);
        let emb = pca.transform(&z);
        // the first principal coordinate must separate the two clusters
        let mean0: f64 =
            (0..n).step_by(2).map(|i| emb[(i, 0)]).sum::<f64>() / (n / 2) as f64;
        let mean1: f64 =
            (1..n).step_by(2).map(|i| emb[(i, 0)]).sum::<f64>() / (n / 2) as f64;
        let spread: f64 = (0..n)
            .map(|i| {
                let m = if i % 2 == 0 { mean0 } else { mean1 };
                (emb[(i, 0)] - m).powi(2)
            })
            .sum::<f64>()
            / n as f64;
        assert!(
            (mean0 - mean1).abs() > 2.0 * spread.sqrt(),
            "clusters not separated: means {mean0} vs {mean1}, sd {}",
            spread.sqrt()
        );
    }
}
