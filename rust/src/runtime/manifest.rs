//! Artifact manifest: what `python/compile/aot.py` produced, with enough
//! geometry for the runtime to pick the right executable per dataset.

use super::json::Json;
use std::path::{Path, PathBuf};

/// One AOT featurize executable (fixed tile geometry).
#[derive(Clone, Debug)]
pub struct FeaturizeArtifact {
    pub name: String,
    pub family: String,
    pub d: usize,
    pub q: usize,
    pub s: usize,
    pub block_b: usize,
    pub block_m: usize,
    pub path: PathBuf,
}

/// One AOT krr-solve executable.
#[derive(Clone, Debug)]
pub struct KrrSolveArtifact {
    pub name: String,
    pub f: usize,
    pub path: PathBuf,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub featurize: Vec<FeaturizeArtifact>,
    pub krr_solve: Vec<KrrSolveArtifact>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| format!("read {:?}: {e}", dir.join("manifest.json")))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest, String> {
        let j = Json::parse(text).map_err(|e| format!("manifest parse: {e}"))?;
        let mut m = Manifest::default();
        let arts = j
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| "manifest missing artifacts[]".to_string())?;
        for a in arts {
            let kind = a.get("kind").and_then(|k| k.as_str()).unwrap_or("");
            let name = a.get("name").and_then(|k| k.as_str()).unwrap_or("").to_string();
            let file = a.get("file").and_then(|k| k.as_str()).unwrap_or("").to_string();
            let path = dir.join(&file);
            match kind {
                "featurize" => m.featurize.push(FeaturizeArtifact {
                    name,
                    family: a.get("family").and_then(|k| k.as_str()).unwrap_or("").to_string(),
                    d: a.get("d").and_then(|k| k.as_usize()).unwrap_or(0),
                    q: a.get("q").and_then(|k| k.as_usize()).unwrap_or(0),
                    s: a.get("s").and_then(|k| k.as_usize()).unwrap_or(1),
                    block_b: a.get("block_b").and_then(|k| k.as_usize()).unwrap_or(256),
                    block_m: a.get("block_m").and_then(|k| k.as_usize()).unwrap_or(128),
                    path,
                }),
                "krr_solve" => m.krr_solve.push(KrrSolveArtifact {
                    name,
                    f: a.get("f").and_then(|k| k.as_usize()).unwrap_or(0),
                    path,
                }),
                other => return Err(format!("unknown artifact kind {other:?}")),
            }
        }
        Ok(m)
    }

    /// Find the featurize artifact for a given (family, d).
    pub fn find_featurize(&self, family: &str, d: usize) -> Option<&FeaturizeArtifact> {
        self.featurize.iter().find(|a| a.family == family && a.d == d)
    }

    pub fn find_krr_solve(&self, f: usize) -> Option<&KrrSolveArtifact> {
        self.krr_solve.iter().find(|a| a.f == f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{"block_b": 256, "block_m": 128, "artifacts": [
        {"name": "featurize_gaussian_d3_q12_s2", "kind": "featurize",
         "family": "gaussian", "d": 3, "q": 12, "s": 2,
         "block_b": 256, "block_m": 128, "file": "featurize_gaussian_d3_q12_s2.hlo.txt"},
        {"name": "krr_solve_f512", "kind": "krr_solve", "f": 512,
         "file": "krr_solve_f512.hlo.txt"}
    ]}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.featurize.len(), 1);
        assert_eq!(m.krr_solve.len(), 1);
        let f = m.find_featurize("gaussian", 3).unwrap();
        assert_eq!((f.q, f.s, f.block_b, f.block_m), (12, 2, 256, 128));
        assert!(f.path.to_str().unwrap().starts_with("/tmp/a/"));
        assert!(m.find_featurize("gaussian", 99).is_none());
        assert_eq!(m.find_krr_solve(512).unwrap().name, "krr_solve_f512");
    }

    #[test]
    fn real_manifest_if_present() {
        // integration: parse the checked-out artifacts/manifest.json when
        // `make artifacts` has run
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.featurize.is_empty());
            assert!(m.find_featurize("gaussian", 3).is_some());
            for f in &m.featurize {
                assert!(f.path.exists(), "missing {:?}", f.path);
            }
        }
    }
}
