//! Std-only stand-in for the PJRT runtime, compiled when the `pjrt` cargo
//! feature is off (the offline registry has no XLA bindings).
//!
//! The API mirrors `runtime::pjrt::Runtime` exactly: opening a manifest
//! works (so `gzk info` and artifact tooling keep functioning), but every
//! execute method returns `Err`, which the coordinator worker treats as
//! "fall back to the native featurizer". This keeps the `Backend::Pjrt`
//! plumbing testable without the accelerator stack.

use super::manifest::Manifest;
use crate::linalg::Mat;
use std::path::Path;

const UNAVAILABLE: &str =
    "PJRT backend unavailable: gzk was built without the `pjrt` cargo feature";

/// Stub runtime: manifest-aware, execution-free.
pub struct Runtime {
    manifest: Manifest,
}

impl Runtime {
    /// Open the artifact directory (must contain manifest.json).
    pub fn open(dir: &Path) -> Result<Runtime, String> {
        Ok(Runtime { manifest: Manifest::load(dir)? })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Always errors in the stub build; callers fall back to native.
    pub fn featurize(&self, _family: &str, _x: &Mat, _w: &Mat) -> Result<Mat, String> {
        Err(UNAVAILABLE.to_string())
    }

    /// Always errors in the stub build; callers fall back to native.
    pub fn krr_solve(&self, _g: &Mat, _b: &[f64], _lambda: f64) -> Result<Vec<f64>, String> {
        Err(UNAVAILABLE.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_fails_without_manifest() {
        assert!(Runtime::open(Path::new("/definitely/not/a/dir")).is_err());
    }

    #[test]
    fn execute_methods_error() {
        let rt = Runtime { manifest: Manifest::default() };
        let x = Mat::zeros(2, 3);
        let w = Mat::zeros(4, 3);
        assert!(rt.featurize("gaussian", &x, &w).is_err());
        assert!(rt.krr_solve(&Mat::zeros(2, 2), &[0.0, 0.0], 0.1).is_err());
    }
}
