//! Minimal JSON parser — just enough for `artifacts/manifest.json`
//! (objects, arrays, strings, numbers, booleans, null). serde is not
//! available in the offline registry; the manifest grammar is small and
//! fixed, so a ~150-line recursive-descent parser is the honest substrate.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Nesting bound. Client-controlled bytes reach this parser over the
/// serving wire (`server::wire::parse_request`), and every `[`/`{` level
/// costs a stack frame — unbounded, a few hundred KB of `[` overflows
/// the reader thread's stack, which aborts the whole process (a stack
/// overflow is not a catchable panic). 128 is far deeper than any
/// document this crate produces or accepts.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(c @ (b'{' | b'[')) => {
                if self.depth >= MAX_DEPTH {
                    return Err(format!(
                        "nesting deeper than {MAX_DEPTH} levels at byte {}",
                        self.i
                    ));
                }
                self.depth += 1;
                let v = if c == b'{' { self.object() } else { self.array() };
                self.depth -= 1;
                v
            }
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        // accumulate raw bytes: the input is already valid UTF-8, and
        // `"`/`\` are ASCII so they can never split a multi-byte char —
        // pushing bytes (not `byte as char`, which is Latin-1 and
        // mangles every non-ASCII char) keeps multi-byte input intact
        let mut out: Vec<u8> = Vec::new();
        while let Some(c) = self.peek() {
            self.i += 1;
            match c {
                b'"' => {
                    return String::from_utf8(out)
                        .map_err(|_| "invalid UTF-8 in string".to_string());
                }
                b'\\' => {
                    let e = self.peek().ok_or("eof in escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push(b'"'),
                        b'\\' => out.push(b'\\'),
                        b'/' => out.push(b'/'),
                        b'n' => out.push(b'\n'),
                        b't' => out.push(b'\t'),
                        b'r' => out.push(b'\r'),
                        b'b' => out.push(8),
                        b'f' => out.push(12),
                        b'u' => {
                            // a truncated escape ("…\u1") must be a parse
                            // error, not an out-of-bounds slice
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or("bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            let ch = char::from_u32(cp).unwrap_or('\u{fffd}');
                            let mut utf8 = [0u8; 4];
                            out.extend_from_slice(ch.encode_utf8(&mut utf8).as_bytes());
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                _ => out.push(c),
            }
        }
        Err("unterminated string".into())
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let text = r#"{"block_b": 256, "artifacts": [
            {"name": "featurize_gaussian_d3_q12_s2", "kind": "featurize",
             "d": 3, "q": 12, "s": 2, "file": "f.hlo.txt"},
            {"name": "krr_solve_f512", "kind": "krr_solve", "f": 512}
        ]}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("block_b").unwrap().as_usize(), Some(256));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 2);
        assert_eq!(arts[0].get("d").unwrap().as_usize(), Some(3));
        assert_eq!(arts[1].get("kind").unwrap().as_str(), Some("krr_solve"));
    }

    #[test]
    fn scalars_and_escapes() {
        assert_eq!(Json::parse("3.5e2").unwrap().as_f64(), Some(350.0));
        assert_eq!(Json::parse("-7").unwrap().as_f64(), Some(-7.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(
            Json::parse(r#""a\n\"b\"""#).unwrap().as_str(),
            Some("a\n\"b\"")
        );
        assert_eq!(Json::parse(r#""A""#).unwrap().as_str(), Some("A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn non_ascii_strings_survive_intact() {
        // multi-byte UTF-8 must pass through byte-exact (per-byte
        // `as char` casts would mangle it into Latin-1 mojibake)
        let j = Json::parse("{\"modèle\":\"café ☕ Ψ\"}").unwrap();
        assert_eq!(j.get("modèle").and_then(|v| v.as_str()), Some("café ☕ Ψ"));
        // \u escapes decode next to raw multi-byte chars
        assert_eq!(Json::parse("\"é\\u00e9\"").unwrap().as_str(), Some("éé"));
    }

    #[test]
    fn hostile_nesting_is_an_error_not_a_stack_overflow() {
        // comfortably inside the bound: parses
        let deep_ok = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&deep_ok).is_ok());
        // far beyond it (this used to abort the process): clean error
        assert!(Json::parse(&"[".repeat(200_000)).is_err());
        assert!(Json::parse(&r#"{"a":"#.repeat(100_000)).is_err());
        let mixed = format!("{}{}", "[".repeat(64), r#"{"k":"#.repeat(100_000));
        assert!(Json::parse(&mixed).is_err());
    }

    #[test]
    fn truncated_unicode_escape_is_an_error_not_a_panic() {
        for bad in [r#""\u"#, r#""\u1"#, r#""\u12"#, r#""\u123"#, r#""\uZZZZ""#] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
        // a complete escape still decodes
        assert_eq!(Json::parse("\"\\u0041\"").unwrap().as_str(), Some("A"));
    }

    #[test]
    fn nested_arrays() {
        let j = Json::parse("[[1,2],[3]]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_arr().unwrap()[1].as_f64(), Some(2.0));
    }
}
