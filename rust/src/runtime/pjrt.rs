//! The real PJRT execution path, compiled only with `--features pjrt`
//! (requires the `xla` bindings, which are not in the offline registry —
//! add the dependency in Cargo.toml when building on a machine that has
//! them). API-identical to `runtime::stub::Runtime`.

use super::manifest::Manifest;
use crate::linalg::Mat;
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A PJRT CPU client plus a cache of compiled executables.
///
/// Not `Send`: each coordinator worker thread builds its own `Runtime`
/// (PJRT handles are raw pointers). Compilation happens lazily on first
/// use of each artifact and is amortized across the run.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    #[allow(dead_code)]
    dir: PathBuf,
    exes: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

fn err<E: std::fmt::Debug>(what: &str) -> impl Fn(E) -> String + '_ {
    move |e| format!("{what}: {e:?}")
}

impl Runtime {
    /// Open the artifact directory (must contain manifest.json).
    pub fn open(dir: &Path) -> Result<Runtime, String> {
        let client = xla::PjRtClient::cpu().map_err(err("PJRT CPU client"))?;
        let manifest = Manifest::load(dir)?;
        Ok(Runtime { client, manifest, dir: dir.to_path_buf(), exes: RefCell::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn executable(&self, name: &str, path: &Path) -> Result<(), String> {
        if self.exes.borrow().contains_key(name) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| "artifact path not utf-8".to_string())?,
        )
        .map_err(err("parse HLO text"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(err("compile"))?;
        self.exes.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    fn run2(&self, name: &str, a: xla::Literal, b: xla::Literal) -> Result<xla::Literal, String> {
        let exes = self.exes.borrow();
        let exe = exes.get(name).expect("executable cached");
        let out = exe.execute::<xla::Literal>(&[a, b]).map_err(err("execute"))?;
        let lit = out[0][0].to_literal_sync().map_err(err("fetch output"))?;
        lit.to_tuple1().map_err(err("untuple output"))
    }

    fn run3(
        &self,
        name: &str,
        a: xla::Literal,
        b: xla::Literal,
        c: xla::Literal,
    ) -> Result<xla::Literal, String> {
        let exes = self.exes.borrow();
        let exe = exes.get(name).expect("executable cached");
        let out = exe.execute::<xla::Literal>(&[a, b, c]).map_err(err("execute"))?;
        let lit = out[0][0].to_literal_sync().map_err(err("fetch output"))?;
        lit.to_tuple1().map_err(err("untuple output"))
    }

    /// Featurize `x` (n x d) against `w` (m x d) through the AOT executable
    /// for (family, d). Pads rows to the artifact's block_b and chunks
    /// directions in block_m groups; output is (n, m*s) scaled for a total
    /// direction count of m (Def.-8 1/sqrt(m)).
    pub fn featurize(&self, family: &str, x: &Mat, w: &Mat) -> Result<Mat, String> {
        let d = x.cols();
        let art = self
            .manifest
            .find_featurize(family, d)
            .ok_or_else(|| format!("no featurize artifact for family={family} d={d}"))?
            .clone();
        if w.cols() != d {
            return Err("direction dimension mismatch".to_string());
        }
        if w.rows() % art.block_m != 0 {
            return Err(format!(
                "direction count {} must be a multiple of artifact block_m {}",
                w.rows(),
                art.block_m
            ));
        }
        self.executable(&art.name, &art.path)?;

        let (n, m, s) = (x.rows(), w.rows(), art.s);
        let (bb, bm) = (art.block_b, art.block_m);
        let n_pad = n.div_ceil(bb) * bb;
        // the graph embeds 1/sqrt(block_m); rescale for m total directions
        let rescale = ((bm as f64) / (m as f64)).sqrt() as f32;

        let mut out = Mat::zeros(n, m * s);
        let mut x_block = vec![0.0f32; bb * d];
        for rb in (0..n_pad).step_by(bb) {
            let rows_here = bb.min(n.saturating_sub(rb));
            if rows_here == 0 {
                break;
            }
            x_block.fill(0.0);
            for r in 0..rows_here {
                for c in 0..d {
                    x_block[r * d + c] = x[(rb + r, c)] as f32;
                }
            }
            let x_lit = xla::Literal::vec1(&x_block)
                .reshape(&[bb as i64, d as i64])
                .map_err(err("reshape x"))?;
            for mb in (0..m).step_by(bm) {
                let mut w_block = vec![0.0f32; bm * d];
                for r in 0..bm {
                    for c in 0..d {
                        w_block[r * d + c] = w[(mb + r, c)] as f32;
                    }
                }
                let w_lit = xla::Literal::vec1(&w_block)
                    .reshape(&[bm as i64, d as i64])
                    .map_err(err("reshape w"))?;
                let z = self.run2(&art.name, x_lit.clone(), w_lit)?;
                let zv = z.to_vec::<f32>().map_err(err("read z"))?;
                debug_assert_eq!(zv.len(), bb * bm * s);
                for r in 0..rows_here {
                    let orow = out.row_mut(rb + r);
                    for c in 0..bm * s {
                        orow[mb * s + c] = (zv[r * bm * s + c] * rescale) as f64;
                    }
                }
            }
        }
        Ok(out)
    }

    /// Solve (G + lambda I) w = b through the AOT Cholesky graph. G must be
    /// exactly the artifact dimension.
    pub fn krr_solve(&self, g: &Mat, b: &[f64], lambda: f64) -> Result<Vec<f64>, String> {
        let f = g.rows();
        let art = self
            .manifest
            .find_krr_solve(f)
            .ok_or_else(|| format!("no krr_solve artifact for F={f}"))?
            .clone();
        self.executable(&art.name, &art.path)?;
        let gf: Vec<f32> = g.data().iter().map(|&v| v as f32).collect();
        let bf: Vec<f32> = b.iter().map(|&v| v as f32).collect();
        let g_lit = xla::Literal::vec1(&gf)
            .reshape(&[f as i64, f as i64])
            .map_err(err("reshape g"))?;
        let b_lit = xla::Literal::vec1(&bf).reshape(&[f as i64]).map_err(err("reshape b"))?;
        let l_lit = xla::Literal::scalar(lambda as f32);
        let wout = self.run3(&art.name, g_lit, b_lit, l_lit)?;
        Ok(wout.to_vec::<f32>().map_err(err("read w"))?.into_iter().map(|v| v as f64).collect())
    }
}
