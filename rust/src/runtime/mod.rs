//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from the rust hot path.
//!
//! HLO *text* is the interchange format (xla_extension 0.5.1 rejects
//! jax >= 0.5 protos with 64-bit ids; the text parser reassigns ids — see
//! /opt/xla-example/README.md). Python never runs here: the executables are
//! compiled once per process by the PJRT CPU client and cached.

mod json;
mod manifest;

pub use json::Json;
pub use manifest::{FeaturizeArtifact, KrrSolveArtifact, Manifest};

use crate::linalg::Mat;
use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A PJRT CPU client plus a cache of compiled executables.
///
/// Not `Send`: each coordinator worker thread builds its own `Runtime`
/// (PJRT handles are raw pointers). Compilation happens lazily on first
/// use of each artifact and is amortized across the run.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    exes: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl Runtime {
    /// Open the artifact directory (must contain manifest.json).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let manifest = Manifest::load(dir)?;
        Ok(Runtime { client, manifest, dir: dir.to_path_buf(), exes: RefCell::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    fn executable(&self, name: &str, path: &Path) -> Result<()> {
        if self.exes.borrow().contains_key(name) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile {name}"))?;
        self.exes.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    fn run2(&self, name: &str, a: xla::Literal, b: xla::Literal) -> Result<xla::Literal> {
        let exes = self.exes.borrow();
        let exe = exes.get(name).expect("executable cached");
        let out = exe.execute::<xla::Literal>(&[a, b])?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple1()?)
    }

    fn run3(
        &self,
        name: &str,
        a: xla::Literal,
        b: xla::Literal,
        c: xla::Literal,
    ) -> Result<xla::Literal> {
        let exes = self.exes.borrow();
        let exe = exes.get(name).expect("executable cached");
        let out = exe.execute::<xla::Literal>(&[a, b, c])?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple1()?)
    }

    /// Featurize `x` (n x d) against `w` (m x d) through the AOT executable
    /// for (family, d). Pads rows to the artifact's block_b and chunks
    /// directions in block_m groups; output is (n, m*s) scaled for a total
    /// direction count of m (Def.-8 1/sqrt(m)).
    pub fn featurize(&self, family: &str, x: &Mat, w: &Mat) -> Result<Mat> {
        let d = x.cols();
        let art = self
            .manifest
            .find_featurize(family, d)
            .with_context(|| format!("no featurize artifact for family={family} d={d}"))?
            .clone();
        anyhow::ensure!(w.cols() == d, "direction dimension mismatch");
        anyhow::ensure!(
            w.rows() % art.block_m == 0,
            "direction count {} must be a multiple of artifact block_m {}",
            w.rows(),
            art.block_m
        );
        self.executable(&art.name, &art.path)?;

        let (n, m, s) = (x.rows(), w.rows(), art.s);
        let (bb, bm) = (art.block_b, art.block_m);
        let n_pad = n.div_ceil(bb) * bb;
        // the graph embeds 1/sqrt(block_m); rescale for m total directions
        let rescale = ((bm as f64) / (m as f64)).sqrt() as f32;

        let mut out = Mat::zeros(n, m * s);
        let mut x_block = vec![0.0f32; bb * d];
        for rb in (0..n_pad).step_by(bb) {
            let rows_here = bb.min(n.saturating_sub(rb));
            if rows_here == 0 {
                break;
            }
            x_block.fill(0.0);
            for r in 0..rows_here {
                for c in 0..d {
                    x_block[r * d + c] = x[(rb + r, c)] as f32;
                }
            }
            let x_lit = xla::Literal::vec1(&x_block).reshape(&[bb as i64, d as i64])?;
            for mb in (0..m).step_by(bm) {
                let mut w_block = vec![0.0f32; bm * d];
                for r in 0..bm {
                    for c in 0..d {
                        w_block[r * d + c] = w[(mb + r, c)] as f32;
                    }
                }
                let w_lit = xla::Literal::vec1(&w_block).reshape(&[bm as i64, d as i64])?;
                let z = self.run2(&art.name, x_lit.clone(), w_lit)?;
                let zv = z.to_vec::<f32>()?;
                debug_assert_eq!(zv.len(), bb * bm * s);
                for r in 0..rows_here {
                    let orow = out.row_mut(rb + r);
                    for c in 0..bm * s {
                        orow[mb * s + c] = (zv[r * bm * s + c] * rescale) as f64;
                    }
                }
            }
        }
        Ok(out)
    }

    /// Solve (G + lambda I) w = b through the AOT Cholesky graph. G must be
    /// exactly the artifact dimension.
    pub fn krr_solve(&self, g: &Mat, b: &[f64], lambda: f64) -> Result<Vec<f64>> {
        let f = g.rows();
        let art = self
            .manifest
            .find_krr_solve(f)
            .with_context(|| format!("no krr_solve artifact for F={f}"))?
            .clone();
        self.executable(&art.name, &art.path)?;
        let gf: Vec<f32> = g.data().iter().map(|&v| v as f32).collect();
        let bf: Vec<f32> = b.iter().map(|&v| v as f32).collect();
        let g_lit = xla::Literal::vec1(&gf).reshape(&[f as i64, f as i64])?;
        let b_lit = xla::Literal::vec1(&bf).reshape(&[f as i64])?;
        let l_lit = xla::Literal::scalar(lambda as f32);
        let wout = self.run3(&art.name, g_lit, b_lit, l_lit)?;
        Ok(wout.to_vec::<f32>()?.into_iter().map(|v| v as f64).collect())
    }
}

/// Default artifact directory: `$GZK_ARTIFACTS` or `<crate>/artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("GZK_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}
