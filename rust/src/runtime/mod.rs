//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from the rust hot path.
//!
//! HLO *text* is the interchange format (xla_extension 0.5.1 rejects
//! jax >= 0.5 protos with 64-bit ids; the text parser reassigns ids — see
//! /opt/xla-example/README.md). Python never runs here: the executables are
//! compiled once per process by the PJRT CPU client and cached.
//!
//! The XLA bindings are not available in the offline registry, so the real
//! execution path (`runtime::pjrt`) lives behind the `pjrt` cargo feature.
//! The default build uses `runtime::stub`: the same `Runtime` API, manifest
//! loading included, whose execute methods return `Err` so callers (the
//! coordinator worker, the CLI) fall back to the native featurizer.

mod json;
mod manifest;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

pub use json::Json;
pub use manifest::{FeaturizeArtifact, KrrSolveArtifact, Manifest};

use std::path::{Path, PathBuf};

/// Default artifact directory: `$GZK_ARTIFACTS` or `<crate>/artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("GZK_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}
