//! PRNG substrate: xoshiro256++ with splitmix64 seeding.
//!
//! The coordinator's one-round protocol relies on *identical streams* from a
//! shared seed: the leader broadcasts `(seed, m)` and every worker derives
//! the same direction set `w_1..w_m ~ U(S^{d-1})` without communication.
//! Determinism across threads/processes is therefore load-bearing and is
//! covered by tests below and by property tests in the coordinator.

/// xoshiro256++ by Blackman & Vigna — fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from the Box-Muller pair
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (e.g. per worker / per subsystem) from a
    /// label. Used so the broadcast seed yields decorrelated substreams.
    pub fn fork(&self, label: u64) -> Rng {
        let mut sm = self
            .s[0]
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(label.wrapping_mul(0xD134_2543_DE82_EF95))
            .wrapping_add(self.s[3]);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free-enough for our sizes
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Fill a slice with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Rademacher +/-1.
    #[inline]
    pub fn rademacher(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Uniform direction on S^{d-1} written into `out` (length d).
    pub fn sphere(&mut self, out: &mut [f64]) {
        loop {
            self.fill_normal(out);
            let norm: f64 = out.iter().map(|v| v * v).sum::<f64>().sqrt();
            if norm > 1e-12 {
                for v in out.iter_mut() {
                    *v /= norm;
                }
                return;
            }
        }
    }

    /// m uniform directions on S^{d-1}, row-major (m x d).
    pub fn sphere_matrix(&mut self, m: usize, d: usize) -> Vec<f64> {
        let mut out = vec![0.0; m * d];
        for row in out.chunks_mut(d) {
            self.sphere(row);
        }
        out
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from 0..n (k <= n) by partial shuffle.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Chi-distributed sample with k degrees of freedom (norm of k normals).
    pub fn chi(&mut self, k: usize) -> f64 {
        (0..k).map(|_| { let z = self.normal(); z * z }).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same == 0);
    }

    #[test]
    fn fork_is_deterministic_and_decorrelated() {
        let base = Rng::new(7);
        let mut f1 = base.fork(0);
        let mut f1b = base.fork(0);
        let mut f2 = base.fork(1);
        for _ in 0..100 {
            assert_eq!(f1.next_u64(), f1b.next_u64());
        }
        let mut f1 = base.fork(0);
        let same = (0..1000).filter(|_| f1.next_u64() == f2.next_u64()).count();
        assert!(same == 0);
    }

    #[test]
    fn uniform_moments() {
        let mut rng = Rng::new(3);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
            sumsq += u * u;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 0.005, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var {var}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(4);
        let n = 400_000;
        let (mut m1, mut m2, mut m4) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            m1 += z;
            m2 += z * z;
            m4 += z * z * z * z;
        }
        assert!((m1 / n as f64).abs() < 0.01);
        assert!((m2 / n as f64 - 1.0).abs() < 0.02);
        assert!((m4 / n as f64 - 3.0).abs() < 0.1); // kurtosis of N(0,1)
    }

    #[test]
    fn sphere_is_unit_and_isotropic() {
        let mut rng = Rng::new(5);
        let d = 6;
        let n = 50_000;
        let mut mean = vec![0.0; d];
        let mut buf = vec![0.0; d];
        for _ in 0..n {
            rng.sphere(&mut buf);
            let norm: f64 = buf.iter().map(|v| v * v).sum::<f64>();
            assert!((norm - 1.0).abs() < 1e-10);
            for (m, v) in mean.iter_mut().zip(&buf) {
                *m += v;
            }
        }
        for m in &mean {
            assert!((m / n as f64).abs() < 0.01);
        }
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut rng = Rng::new(6);
        for _ in 0..50 {
            let idx = rng.sample_indices(100, 30);
            assert_eq!(idx.len(), 30);
            let mut seen = [false; 100];
            for &i in &idx {
                assert!(i < 100);
                assert!(!seen[i], "duplicate index");
                seen[i] = true;
            }
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(8);
        let mut xs: Vec<usize> = (0..64).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn chi_mean_approx() {
        // E[chi_k] = sqrt(2) Gamma((k+1)/2)/Gamma(k/2); for k=4 ~ 1.8800
        let mut rng = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.chi(4)).sum::<f64>() / n as f64;
        assert!((mean - 1.8800).abs() < 0.01, "{mean}");
    }
}
