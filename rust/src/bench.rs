//! Minimal timing harness for the `harness = false` benches (criterion is
//! not available in the offline registry).

use std::time::Instant;

/// Time a closure: median and mean over `reps` runs after `warmup` runs.
pub fn time_it<T>(warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> Timing {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    Timing { median: samples[samples.len() / 2], mean, min: samples[0], reps }
}

#[derive(Clone, Copy, Debug)]
pub struct Timing {
    pub median: f64,
    pub mean: f64,
    pub min: f64,
    pub reps: usize,
}

impl Timing {
    pub fn pretty(&self) -> String {
        format!("{} (median of {}, min {})", fmt_secs(self.median), self.reps, fmt_secs(self.min))
    }
}

pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

/// Fixed-width table printer for bench outputs (mirrors the paper's table
/// layout so EXPERIMENTS.md can diff directly).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Table {
        Table { headers: headers.into_iter().map(Into::into).collect(), rows: vec![] }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!("{:>width$}  ", c, width = w));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            line(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_runs() {
        let t = time_it(1, 5, || (0..1000).sum::<usize>());
        assert!(t.median >= 0.0 && t.mean >= t.min);
        assert_eq!(t.reps, 5);
    }

    #[test]
    fn formatting() {
        assert!(fmt_secs(2.5e-9).ends_with("ns"));
        assert!(fmt_secs(2.5e-5).ends_with("us"));
        assert!(fmt_secs(2.5e-2).ends_with("ms"));
        assert!(fmt_secs(2.5).ends_with('s'));
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(vec!["method", "mse"]);
        t.row(vec!["gegenbauer", "1.15"]);
        t.print(); // must not panic
    }
}
