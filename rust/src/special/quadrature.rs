//! Gauss quadrature rules, built from scratch.
//!
//! * [`gauss_legendre`] — nodes/weights on [-1, 1] by Newton iteration on
//!   Legendre polynomials (standard Golub-Welsch-free construction).
//! * [`gauss_jacobi`] — nodes/weights for weight (1-t)^a (1+t)^a (the
//!   symmetric Jacobi / Gegenbauer measure used by Eq. (8) of the paper),
//!   by Newton iteration on Jacobi polynomials with Chebyshev-like initial
//!   guesses. Handles the d = 2 Chebyshev case (a = -1/2) exactly.

use super::gamma::lgamma;

/// Gauss-Legendre nodes and weights on [-1, 1].
pub fn gauss_legendre(n: usize) -> (Vec<f64>, Vec<f64>) {
    assert!(n >= 1);
    let mut nodes = vec![0.0; n];
    let mut weights = vec![0.0; n];
    let m = n.div_ceil(2);
    for i in 0..m {
        // Chebyshev-style initial guess for the i-th root (descending order)
        let mut x = (std::f64::consts::PI * (i as f64 + 0.75) / (n as f64 + 0.5)).cos();
        let mut dp = 0.0;
        for _ in 0..100 {
            // evaluate P_n(x) and P_n'(x) by recurrence
            let mut p0 = 1.0;
            let mut p1 = x;
            for k in 2..=n {
                let kf = k as f64;
                let p2 = ((2.0 * kf - 1.0) * x * p1 - (kf - 1.0) * p0) / kf;
                p0 = p1;
                p1 = p2;
            }
            dp = n as f64 * (x * p1 - p0) / (x * x - 1.0);
            let dx = p1 / dp;
            x -= dx;
            if dx.abs() < 1e-15 {
                break;
            }
        }
        nodes[i] = -x;
        nodes[n - 1 - i] = x;
        let w = 2.0 / ((1.0 - x * x) * dp * dp);
        weights[i] = w;
        weights[n - 1 - i] = w;
    }
    (nodes, weights)
}

/// Symmetric Gauss-Jacobi rule: integrates f(t) (1-t^2)^a exactly for
/// polynomials f up to degree 2n-1. `a > -1`.
pub fn gauss_jacobi(n: usize, a: f64) -> (Vec<f64>, Vec<f64>) {
    assert!(n >= 1 && a > -1.0);
    // Chebyshev special case a = -1/2: closed-form Gauss-Chebyshev rule.
    if (a + 0.5).abs() < 1e-14 {
        let nodes: Vec<f64> = (0..n)
            .map(|i| (std::f64::consts::PI * (i as f64 + 0.5) / n as f64).cos())
            .collect();
        let w = std::f64::consts::PI / n as f64;
        return (nodes, vec![w; n]);
    }
    // General symmetric Jacobi (alpha = beta = a): bracket the n simple
    // roots by sign changes on a fine Chebyshev-spaced grid, then polish
    // each with bisection + Newton. Robust for the n <= 512 rules we use.
    let alpha = a;
    let beta = a;
    let mut nodes = Vec::with_capacity(n);
    let mut weights = vec![0.0; n];
    let grid_n = 16 * n;
    let mut prev_x = ((grid_n as f64 - 0.5) / grid_n as f64 * std::f64::consts::PI).cos();
    let mut prev_p = jacobi_and_derivative(n, alpha, beta, prev_x).0;
    for g in (0..grid_n - 1).rev() {
        let x = ((g as f64 + 0.5) / grid_n as f64 * std::f64::consts::PI).cos();
        let p = jacobi_and_derivative(n, alpha, beta, x).0;
        if prev_p == 0.0 {
            nodes.push(prev_x);
        } else if prev_p * p < 0.0 {
            // bisect to tighten, then Newton polish
            let (mut lo, mut hi, mut plo) = (prev_x, x, prev_p);
            for _ in 0..40 {
                let mid = 0.5 * (lo + hi);
                let pm = jacobi_and_derivative(n, alpha, beta, mid).0;
                if plo * pm <= 0.0 {
                    hi = mid;
                } else {
                    lo = mid;
                    plo = pm;
                }
            }
            let mut root = 0.5 * (lo + hi);
            for _ in 0..8 {
                let (pv, dv) = jacobi_and_derivative(n, alpha, beta, root);
                if dv == 0.0 {
                    break;
                }
                let dx = pv / dv;
                root -= dx;
                if dx.abs() < 1e-15 {
                    break;
                }
            }
            nodes.push(root);
        }
        prev_x = x;
        prev_p = p;
    }
    assert_eq!(nodes.len(), n, "Gauss-Jacobi root bracketing missed roots (a = {a})");
    for i in 0..n {
        let x = nodes[i];
        let dp = jacobi_and_derivative(n, alpha, beta, x).1;
        // Gauss-Jacobi weight: w_i = G_n / ((1 - x_i^2) [P_n'(x_i)]^2) with
        // G_n = 2^{alpha+beta+1} Gamma(n+alpha+1) Gamma(n+beta+1)
        //       / (Gamma(n+1) Gamma(n+alpha+beta+1)).
        // (Checked against the Legendre case and the n = 1 closed form via
        // the Gamma duplication formula — see unit tests.)
        let nf = n as f64;
        let log_g = (alpha + beta + 1.0) * std::f64::consts::LN_2
            + lgamma(nf + alpha + 1.0)
            + lgamma(nf + beta + 1.0)
            - lgamma(nf + 1.0)
            - lgamma(nf + alpha + beta + 1.0);
        weights[i] = log_g.exp() / ((1.0 - x * x) * dp * dp);
    }
    (nodes, weights)
}

/// Jacobi polynomial P_n^{(alpha,beta)}(x) and its derivative.
fn jacobi_and_derivative(n: usize, alpha: f64, beta: f64, x: f64) -> (f64, f64) {
    if n == 0 {
        return (1.0, 0.0);
    }
    let mut p0 = 1.0;
    let mut p1 = 0.5 * (alpha - beta + (alpha + beta + 2.0) * x);
    for k in 2..=n {
        let kf = k as f64;
        let a1 = 2.0 * kf * (kf + alpha + beta) * (2.0 * kf + alpha + beta - 2.0);
        let a2 = (2.0 * kf + alpha + beta - 1.0) * (alpha * alpha - beta * beta);
        let a3 = (2.0 * kf + alpha + beta - 2.0)
            * (2.0 * kf + alpha + beta - 1.0)
            * (2.0 * kf + alpha + beta);
        let a4 = 2.0 * (kf + alpha - 1.0) * (kf + beta - 1.0) * (2.0 * kf + alpha + beta);
        let p2 = ((a2 + a3 * x) * p1 - a4 * p0) / a1;
        p0 = p1;
        p1 = p2;
    }
    let nf = n as f64;
    // derivative via the identity (2n+a+b) (1-x^2) P_n' =
    //   n (a - b - (2n+a+b) x) P_n + 2 (n+a)(n+b) P_{n-1}
    let dp = (nf * (alpha - beta - (2.0 * nf + alpha + beta) * x) * p1
        + 2.0 * (nf + alpha) * (nf + beta) * p0)
        / ((2.0 * nf + alpha + beta) * (1.0 - x * x));
    (p1, dp)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn integrate(nodes: &[f64], weights: &[f64], f: impl Fn(f64) -> f64) -> f64 {
        nodes.iter().zip(weights).map(|(&x, &w)| w * f(x)).sum()
    }

    #[test]
    fn legendre_polynomial_exactness() {
        let (x, w) = gauss_legendre(8);
        // int t^k dt over [-1,1]
        for k in 0..15usize {
            let exact = if k % 2 == 1 { 0.0 } else { 2.0 / (k as f64 + 1.0) };
            let got = integrate(&x, &w, |t| t.powi(k as i32));
            assert!((got - exact).abs() < 1e-12, "k={k}: {got} vs {exact}");
        }
    }

    #[test]
    fn legendre_smooth_function() {
        let (x, w) = gauss_legendre(64);
        // int exp(t) dt = e - 1/e
        let exact = std::f64::consts::E - 1.0 / std::f64::consts::E;
        assert!((integrate(&x, &w, f64::exp) - exact).abs() < 1e-13);
    }

    #[test]
    fn jacobi_total_mass() {
        // int (1-t^2)^a dt = sqrt(pi) Gamma(a+1)/Gamma(a+3/2)
        for &a in &[-0.5, 0.0, 0.5, 1.0, 2.5, 14.5] {
            let (x, w) = gauss_jacobi(32, a);
            let got = integrate(&x, &w, |_| 1.0);
            let exact =
                (0.5 * std::f64::consts::PI.ln() + lgamma(a + 1.0) - lgamma(a + 1.5)).exp();
            assert!((got - exact).abs() < 1e-10 * exact, "a={a}: {got} vs {exact}");
        }
    }

    #[test]
    fn jacobi_moments() {
        // int t^2 (1-t^2)^a dt = mass * 1/(2a+3)
        for &a in &[0.0, 0.5, 3.0] {
            let (x, w) = gauss_jacobi(24, a);
            let mass = integrate(&x, &w, |_| 1.0);
            let got = integrate(&x, &w, |t| t * t);
            let exact = mass / (2.0 * a + 3.0);
            assert!((got - exact).abs() < 1e-10, "a={a}: {got} vs {exact}");
        }
    }

    #[test]
    fn jacobi_chebyshev_case() {
        let (x, w) = gauss_jacobi(16, -0.5);
        // int cos(t)/sqrt(1-t^2) dt = pi J_0(1) ~ 2.403939430634413
        let got = integrate(&x, &w, f64::cos);
        assert!((got - 2.403_939_430_634_413).abs() < 1e-10, "{got}");
    }

    #[test]
    fn legendre_equals_jacobi_zero() {
        let (xl, wl) = gauss_legendre(12);
        let (xj, wj) = gauss_jacobi(12, 0.0);
        for i in 0..12 {
            assert!((xl[i] - xj[i]).abs() < 1e-10, "node {i}: {} vs {}", xl[i], xj[i]);
            assert!((wl[i] - wj[i]).abs() < 1e-10, "weight {i}");
        }
    }
}
