//! Normalized Gegenbauer polynomials `P_d^l` with `P_d^l(1) = 1` and the
//! series expansion machinery of paper Eqs. (2)-(8).
//!
//! `d = 2` gives Chebyshev-T, `d = 3` Legendre, `d -> inf` monomials.
//! Three-term recurrence (DESIGN.md §2):
//! `P_l = A_l t P_{l-1} + B_l P_{l-2}`, `A_l = (2l+d-4)/(l+d-3)`,
//! `B_l = -(l-1)/(l+d-3)`.

use super::gamma::{lgamma, log_binomial};
use super::quadrature::gauss_jacobi;

/// Recurrence coefficient arrays (A, B) of length q+1; entries l < 2 unused.
pub fn recurrence_coeffs(q: usize, d: usize) -> (Vec<f64>, Vec<f64>) {
    assert!(d >= 2, "dimension must be >= 2");
    let mut a = vec![0.0; q + 1];
    let mut b = vec![0.0; q + 1];
    for l in 2..=q {
        if d == 2 {
            a[l] = 2.0;
            b[l] = -1.0;
        } else {
            a[l] = (2 * l + d - 4) as f64 / (l + d - 3) as f64;
            b[l] = -((l - 1) as f64) / (l + d - 3) as f64;
        }
    }
    (a, b)
}

/// Evaluate P_d^l(t) for a single (l, t).
pub fn gegenbauer_eval(l: usize, d: usize, t: f64) -> f64 {
    let (a, b) = recurrence_coeffs(l, d);
    let mut p0 = 1.0;
    if l == 0 {
        return p0;
    }
    let mut p1 = t;
    for k in 2..=l {
        let p2 = a[k] * t * p1 + b[k] * p0;
        p0 = p1;
        p1 = p2;
    }
    p1
}

/// Evaluate all degrees 0..=q at each t; returns row-major (q+1) x t.len().
pub fn gegenbauer_all(q: usize, d: usize, t: &[f64]) -> Vec<f64> {
    let n = t.len();
    let (a, b) = recurrence_coeffs(q, d);
    let mut out = vec![0.0; (q + 1) * n];
    for j in 0..n {
        out[j] = 1.0;
    }
    if q >= 1 {
        out[n..2 * n].copy_from_slice(t);
    }
    for l in 2..=q {
        let (head, tail) = out.split_at_mut(l * n);
        let pm1 = &head[(l - 1) * n..l * n];
        let pm2 = &head[(l - 2) * n..(l - 1) * n];
        let cur = &mut tail[..n];
        for j in 0..n {
            cur[j] = a[l] * t[j] * pm1[j] + b[l] * pm2[j];
        }
    }
    out
}

/// alpha_{l,d}: dimension of the space of degree-l spherical harmonics in
/// R^d (paper Eq. 4).
pub fn alpha_dim(l: usize, d: usize) -> f64 {
    log_alpha_dim(l, d).exp()
}

/// log alpha_{l,d}, stable for large l and d.
pub fn log_alpha_dim(l: usize, d: usize) -> f64 {
    assert!(d >= 2);
    match l {
        0 => 0.0,
        1 => (d as f64).ln(),
        _ => {
            let a = log_binomial((d + l - 1) as u64, l as u64);
            let b = log_binomial((d + l - 3) as u64, (l - 2) as u64);
            // alpha = exp(a) - exp(b) with a > b
            a + (-((b - a).exp())).ln_1p()
        }
    }
}

/// |S^{d-2}| / |S^{d-1}| = Gamma(d/2) / (sqrt(pi) Gamma((d-1)/2)).
pub fn surface_ratio(d: usize) -> f64 {
    (lgamma(d as f64 / 2.0) - 0.5 * std::f64::consts::PI.ln() - lgamma((d as f64 - 1.0) / 2.0))
        .exp()
}

/// Gegenbauer series coefficients c_0..c_q of `f` on [-1,1] in dimension d
/// (paper Eq. 8), via Gauss-Jacobi quadrature with weight (1-t^2)^{(d-3)/2}.
pub fn gegenbauer_series_coeffs(
    f: impl Fn(f64) -> f64,
    q: usize,
    d: usize,
    n_quad: usize,
) -> Vec<f64> {
    let a = (d as f64 - 3.0) / 2.0;
    let (nodes, weights) = gauss_jacobi(n_quad, a);
    let fvals: Vec<f64> = nodes.iter().map(|&t| f(t)).collect();
    let p = gegenbauer_all(q, d, &nodes);
    let ratio = surface_ratio(d);
    (0..=q)
        .map(|l| {
            let dot: f64 = (0..nodes.len())
                .map(|j| weights[j] * fvals[j] * p[l * nodes.len() + j])
                .sum();
            alpha_dim(l, d) * ratio * dot
        })
        .collect()
}

/// Chebyshev series coefficients (the paper's d = 2 comparison in Fig. 1).
pub fn chebyshev_series_coeffs(f: impl Fn(f64) -> f64, q: usize, n_quad: usize) -> Vec<f64> {
    gegenbauer_series_coeffs(f, q, 2, n_quad)
}

/// Taylor (Maclaurin) coefficients of `f` around 0 up to degree q, by
/// iterated central finite differences on a Chebyshev interpolant — used
/// only for the Fig. 1 comparison where closed forms exist; callers with
/// analytic derivatives should pass them directly to `taylor_from_derivs`.
pub fn taylor_series_coeffs(derivs_at_zero: &[f64]) -> Vec<f64> {
    // c_j = f^(j)(0) / j!
    let mut log_fact = 0.0;
    derivs_at_zero
        .iter()
        .enumerate()
        .map(|(j, &dj)| {
            if j > 0 {
                log_fact += (j as f64).ln();
            }
            dj * (-log_fact).exp()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chebyshev_t(l: usize, t: f64) -> f64 {
        (l as f64 * t.clamp(-1.0, 1.0).acos()).cos()
    }

    fn legendre(l: usize, t: f64) -> f64 {
        let (mut p0, mut p1) = (1.0, t);
        if l == 0 {
            return p0;
        }
        for k in 2..=l {
            let kf = k as f64;
            let p2 = ((2.0 * kf - 1.0) * t * p1 - (kf - 1.0) * p0) / kf;
            p0 = p1;
            p1 = p2;
        }
        p1
    }

    #[test]
    fn d2_is_chebyshev() {
        for l in 0..=10 {
            for i in 0..50 {
                let t = -1.0 + 2.0 * i as f64 / 49.0;
                assert!(
                    (gegenbauer_eval(l, 2, t) - chebyshev_t(l, t)).abs() < 1e-9,
                    "l={l} t={t}"
                );
            }
        }
    }

    #[test]
    fn d3_is_legendre() {
        for l in 0..=10 {
            for i in 0..50 {
                let t = -1.0 + 2.0 * i as f64 / 49.0;
                assert!(
                    (gegenbauer_eval(l, 3, t) - legendre(l, t)).abs() < 1e-10,
                    "l={l} t={t}"
                );
            }
        }
    }

    #[test]
    fn large_d_is_monomial() {
        for l in 0..=5 {
            for &t in &[-0.9, -0.3, 0.2, 0.8] {
                let p = gegenbauer_eval(l, 200_000, t);
                assert!((p - t.powi(l as i32)).abs() < 1e-3, "l={l} t={t}: {p}");
            }
        }
    }

    #[test]
    fn normalized_and_bounded() {
        for &d in &[2usize, 3, 4, 8, 32] {
            for l in 0..=15 {
                assert!((gegenbauer_eval(l, d, 1.0) - 1.0).abs() < 1e-10);
                for i in 0..30 {
                    let t = -1.0 + 2.0 * i as f64 / 29.0;
                    assert!(gegenbauer_eval(l, d, t).abs() <= 1.0 + 1e-9);
                }
            }
        }
    }

    #[test]
    fn explicit_formula_eq2() {
        // paper Eq. (2) with its c_j recursion
        for &d in &[3usize, 5, 8] {
            for &l in &[2usize, 3, 5, 8] {
                let mut c = vec![1.0];
                for j in 0..l / 2 {
                    let prev = c[j];
                    c.push(
                        -prev * ((l - 2 * j) * (l - 2 * j - 1)) as f64
                            / (2.0 * (j + 1) as f64 * (d - 1 + 2 * j) as f64),
                    );
                }
                for i in 0..17 {
                    let t = -0.96 + 0.12 * i as f64;
                    let direct: f64 = c
                        .iter()
                        .enumerate()
                        .map(|(j, &cj)| {
                            cj * t.powi((l - 2 * j) as i32) * (1.0 - t * t).powi(j as i32)
                        })
                        .sum();
                    assert!(
                        (gegenbauer_eval(l, d, t) - direct).abs() < 1e-9,
                        "d={d} l={l} t={t}"
                    );
                }
            }
        }
    }

    #[test]
    fn alpha_values() {
        // d=3: alpha = 2l+1; alpha_{1,d} = d; d=2: alpha = 2 for l>=1
        for l in 0..8 {
            assert!((alpha_dim(l, 3) - (2 * l + 1) as f64).abs() < 1e-9);
        }
        for &d in &[3usize, 7, 20] {
            assert!((alpha_dim(1, d) - d as f64).abs() < 1e-9);
        }
        for l in 1..8 {
            assert!((alpha_dim(l, 2) - 2.0).abs() < 1e-9);
        }
    }

    #[test]
    fn gegenbauer_all_matches_eval() {
        let t: Vec<f64> = (0..21).map(|i| -1.0 + 0.1 * i as f64).collect();
        for &d in &[2usize, 5, 9] {
            let all = gegenbauer_all(12, d, &t);
            for l in 0..=12 {
                for (j, &tj) in t.iter().enumerate() {
                    assert!((all[l * t.len() + j] - gegenbauer_eval(l, d, tj)).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn orthogonality_eq3() {
        // int P_l P_l' (1-t^2)^{(d-3)/2} = 1_{l=l'} / (alpha_{l,d} ratio)
        for &d in &[2usize, 3, 5, 9] {
            let (nodes, weights) = gauss_jacobi(128, (d as f64 - 3.0) / 2.0);
            let p = gegenbauer_all(8, d, &nodes);
            let ratio = surface_ratio(d);
            for l in 0..=8usize {
                for lp in 0..=8usize {
                    let dot: f64 = (0..nodes.len())
                        .map(|j| weights[j] * p[l * nodes.len() + j] * p[lp * nodes.len() + j])
                        .sum();
                    if l == lp {
                        let expect = 1.0 / (alpha_dim(l, d) * ratio);
                        assert!(
                            (dot - expect).abs() < 1e-8 * expect.max(1.0),
                            "d={d} l={l}: {dot} vs {expect}"
                        );
                    } else {
                        assert!(dot.abs() < 1e-9, "d={d} l={l} lp={lp}: {dot}");
                    }
                }
            }
        }
    }

    #[test]
    fn series_reconstructs_exp() {
        // Fig. 1 setup: kappa(t) = exp(2t) to degree 15
        for &d in &[2usize, 4, 8, 32] {
            let c = gegenbauer_series_coeffs(|t| (2.0 * t).exp(), 15, d, 256);
            let mut max_err: f64 = 0.0;
            for i in 0..501 {
                let t = -1.0 + 2.0 * i as f64 / 500.0;
                let p = gegenbauer_all(15, d, &[t]);
                let approx: f64 = (0..=15).map(|l| c[l] * p[l]).sum();
                max_err = max_err.max((approx - (2.0 * t).exp()).abs());
            }
            assert!(max_err < 1e-6, "d={d}: {max_err}");
            assert!(c.iter().all(|&cl| cl >= -1e-9), "Schoenberg c_l >= 0");
        }
    }

    #[test]
    fn series_exact_for_polynomial() {
        let c = gegenbauer_series_coeffs(|t| t * t * t, 8, 5, 64);
        for l in 4..=8 {
            assert!(c[l].abs() < 1e-12);
        }
        let p = gegenbauer_all(8, 5, &[0.37]);
        let approx: f64 = (0..=8).map(|l| c[l] * p[l]).sum();
        assert!((approx - 0.37f64.powi(3)).abs() < 1e-12);
    }

    #[test]
    fn taylor_coeffs() {
        // exp(2t): f^(j)(0) = 2^j
        let derivs: Vec<f64> = (0..10).map(|j| 2f64.powi(j)).collect();
        let c = taylor_series_coeffs(&derivs);
        assert!((c[0] - 1.0).abs() < 1e-12);
        assert!((c[1] - 2.0).abs() < 1e-12);
        assert!((c[3] - 8.0 / 6.0).abs() < 1e-12);
    }
}
