//! Log-gamma via the Lanczos approximation (g = 7, n = 9 coefficients).
//!
//! Accuracy ~1e-13 relative over the positive reals, which is far more than
//! the radial tables need (they exponentiate differences of lgammas of
//! moderate arguments).

/// Lanczos coefficients for g = 7.
const LANCZOS_G: f64 = 7.0;
const LANCZOS: [f64; 9] = [
    0.999_999_999_999_809_93,
    676.520_368_121_885_1,
    -1_259.139_216_722_402_8,
    771.323_428_777_653_13,
    -176.615_029_162_140_6,
    12.507_343_278_686_905,
    -0.138_571_095_265_720_12,
    9.984_369_578_019_571_6e-6,
    1.505_632_735_149_311_6e-7,
];

/// Natural log of the Gamma function for x > 0.
pub fn lgamma(x: f64) -> f64 {
    assert!(x > 0.0, "lgamma requires x > 0, got {x}");
    if x < 0.5 {
        // reflection: Gamma(x) Gamma(1-x) = pi / sin(pi x)
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - lgamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = LANCZOS[0];
    let t = x + LANCZOS_G + 0.5;
    for (i, &c) in LANCZOS.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// log of the binomial coefficient C(n, k) for 0 <= k <= n.
pub fn log_binomial(n: u64, k: u64) -> f64 {
    assert!(k <= n, "log_binomial requires k <= n");
    lgamma((n + 1) as f64) - lgamma((k + 1) as f64) - lgamma((n - k + 1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factorials() {
        // Gamma(n+1) = n!
        let facts = [1.0, 1.0, 2.0, 6.0, 24.0, 120.0, 720.0, 5040.0];
        for (n, &f) in facts.iter().enumerate() {
            let got = lgamma(n as f64 + 1.0).exp();
            assert!(
                (got - f).abs() / f < 1e-12,
                "Gamma({}) = {got}, want {f}",
                n + 1
            );
        }
    }

    #[test]
    fn half_integers() {
        // Gamma(1/2) = sqrt(pi), Gamma(3/2) = sqrt(pi)/2
        let sp = std::f64::consts::PI.sqrt();
        assert!((lgamma(0.5).exp() - sp).abs() < 1e-12);
        assert!((lgamma(1.5).exp() - sp / 2.0).abs() < 1e-12);
        assert!((lgamma(2.5).exp() - 3.0 * sp / 4.0).abs() < 1e-12);
    }

    #[test]
    fn recurrence_property() {
        // lgamma(x+1) = lgamma(x) + ln(x)
        for i in 1..200 {
            let x = i as f64 * 0.37 + 0.1;
            let lhs = lgamma(x + 1.0);
            let rhs = lgamma(x) + x.ln();
            assert!((lhs - rhs).abs() < 1e-10 * lhs.abs().max(1.0), "x={x}");
        }
    }

    #[test]
    fn large_arguments_stirling() {
        // Stirling: lgamma(x) ~ (x-1/2)ln x - x + ln(2 pi)/2 + 1/(12x)
        for &x in &[50.0f64, 500.0, 5000.0] {
            let stirling = (x - 0.5) * x.ln() - x + 0.5 * (2.0 * std::f64::consts::PI).ln()
                + 1.0 / (12.0 * x);
            assert!((lgamma(x) - stirling).abs() < 1e-6);
        }
    }

    #[test]
    fn binomials() {
        assert!((log_binomial(10, 3).exp() - 120.0).abs() < 1e-9);
        assert!((log_binomial(52, 5).exp() - 2_598_960.0).abs() < 1e-3);
        assert_eq!(log_binomial(7, 0), 0.0);
    }
}
