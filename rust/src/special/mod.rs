//! Special-function substrate: log-gamma, Gegenbauer polynomials,
//! Gauss quadrature and Gegenbauer series expansion.
//!
//! These mirror `python/compile/gegenbauer.py` exactly (same recurrence,
//! same normalization); the cross-language agreement is tested in
//! `rust/tests/parity.rs` through the PJRT artifacts.

mod gamma;
mod gegenbauer;
mod quadrature;
pub mod series;

pub use gamma::{lgamma, log_binomial};
pub use gegenbauer::{
    alpha_dim, gegenbauer_all, gegenbauer_eval, gegenbauer_series_coeffs, log_alpha_dim,
    recurrence_coeffs, surface_ratio, taylor_series_coeffs, chebyshev_series_coeffs,
};
pub use quadrature::{gauss_jacobi, gauss_legendre};
