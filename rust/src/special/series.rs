//! Truncated power-series arithmetic — the substrate for the Fig.-1
//! "Taylor" baseline. The NTK's Maclaurin coefficients are not tabulated
//! anywhere, so we compute them exactly by composing the series of the
//! arc-cosine kernels a0/a1 through the [ZHA+21] recursion.
//!
//! All series are Maclaurin (around 0) with `n` coefficients; composition
//! g(f(x)) handles f(0) != 0 by Taylor-shifting g analytically (binomial
//! expansions of sqrt(1 - t^2) and 1/sqrt(1 - t^2) around the constant).

/// Truncated Maclaurin series: c[0] + c[1] x + ... + c[n-1] x^{n-1}.
#[derive(Clone, Debug)]
pub struct Series {
    pub c: Vec<f64>,
}

impl Series {
    pub fn zero(n: usize) -> Series {
        Series { c: vec![0.0; n] }
    }

    pub fn constant(v: f64, n: usize) -> Series {
        let mut s = Series::zero(n);
        s.c[0] = v;
        s
    }

    pub fn identity(n: usize) -> Series {
        let mut s = Series::zero(n);
        if n > 1 {
            s.c[1] = 1.0;
        }
        s
    }

    pub fn len(&self) -> usize {
        self.c.len()
    }

    pub fn is_empty(&self) -> bool {
        self.c.is_empty()
    }

    pub fn add(&self, other: &Series) -> Series {
        let n = self.len().min(other.len());
        Series { c: (0..n).map(|i| self.c[i] + other.c[i]).collect() }
    }

    pub fn scale(&self, v: f64) -> Series {
        Series { c: self.c.iter().map(|&x| x * v).collect() }
    }

    pub fn mul(&self, other: &Series) -> Series {
        let n = self.len().min(other.len());
        let mut out = vec![0.0; n];
        for i in 0..n {
            if self.c[i] == 0.0 {
                continue;
            }
            for j in 0..n - i {
                out[i + j] += self.c[i] * other.c[j];
            }
        }
        Series { c: out }
    }

    /// Antiderivative with constant 0.
    pub fn integrate(&self) -> Series {
        let n = self.len();
        let mut out = vec![0.0; n];
        for i in 0..n - 1 {
            out[i + 1] = self.c[i] / (i + 1) as f64;
        }
        Series { c: out }
    }

    /// Compose self(g(x)) where g has ZERO constant term.
    pub fn compose0(&self, g: &Series) -> Series {
        assert!(g.c[0].abs() < 1e-14, "compose0 requires g(0) = 0");
        let n = self.len().min(g.len());
        // Horner on series: result = c[n-1]; result = result*g + c[i]
        let mut out = Series::constant(self.c[n - 1], n);
        for i in (0..n - 1).rev() {
            out = out.mul(g);
            out.c[0] += self.c[i];
        }
        out
    }

    /// Evaluate the truncated polynomial at t.
    pub fn eval(&self, t: f64) -> f64 {
        let mut acc = 0.0;
        for &ci in self.c.iter().rev() {
            acc = acc * t + ci;
        }
        acc
    }
}

/// Series of (1 + a x)^alpha (binomial series), n coefficients.
pub fn binomial_series(alpha: f64, a: f64, n: usize) -> Series {
    let mut c = vec![0.0; n];
    c[0] = 1.0;
    let mut term = 1.0;
    for k in 1..n {
        term *= (alpha - (k as f64 - 1.0)) / k as f64 * a;
        c[k] = term;
    }
    Series { c }
}

/// Series of acos(x0 + u) in u (|x0| < 1), n coefficients:
/// acos(x0 + u) = acos(x0) - integral of (1 - (x0+u)^2)^{-1/2} du, with
/// (1-(x0+u)^2)^{-1/2} = ((1-x0)(1+x0))^{-1/2} (1 - u/(1-x0))^{-1/2}
///                       (1 + u/(1+x0))^{-1/2}.
pub fn acos_series(x0: f64, n: usize) -> Series {
    assert!(x0.abs() < 1.0, "acos series needs |x0| < 1");
    let pref = 1.0 / ((1.0 - x0) * (1.0 + x0)).sqrt();
    let f1 = binomial_series(-0.5, -1.0 / (1.0 - x0), n);
    let f2 = binomial_series(-0.5, 1.0 / (1.0 + x0), n);
    let integrand = f1.mul(&f2).scale(pref);
    let mut out = integrand.integrate().scale(-1.0);
    out.c[0] = x0.acos();
    out
}

/// Series of sqrt(1 - (x0 + u)^2) in u, n coefficients.
pub fn sqrt_one_minus_sq_series(x0: f64, n: usize) -> Series {
    assert!(x0.abs() < 1.0);
    let pref = ((1.0 - x0) * (1.0 + x0)).sqrt();
    let f1 = binomial_series(0.5, -1.0 / (1.0 - x0), n);
    let f2 = binomial_series(0.5, 1.0 / (1.0 + x0), n);
    f1.mul(&f2).scale(pref)
}

/// Series of the arc-cosine kernel a0 at x0: a0(t) = 1 - acos(t)/pi.
pub fn a0_series(x0: f64, n: usize) -> Series {
    let mut s = acos_series(x0, n).scale(-1.0 / std::f64::consts::PI);
    s.c[0] += 1.0;
    s
}

/// Series of the arc-cosine kernel a1 at x0:
/// a1(t) = (sqrt(1-t^2) + t (pi - acos t)) / pi.
pub fn a1_series(x0: f64, n: usize) -> Series {
    let pi = std::f64::consts::PI;
    let sq = sqrt_one_minus_sq_series(x0, n);
    // t as a series in u around x0: x0 + u
    let mut t = Series::zero(n);
    t.c[0] = x0;
    if n > 1 {
        t.c[1] = 1.0;
    }
    let mut pia = acos_series(x0, n).scale(-1.0);
    pia.c[0] += pi;
    sq.add(&t.mul(&pia)).scale(1.0 / pi)
}

/// Compose `outer_at(c)` with an inner series f (general constant term):
/// result(u) = outer(f(u)) where outer_at builds outer's series at f(0).
fn compose_shifted(outer_at: impl Fn(f64, usize) -> Series, f: &Series) -> Series {
    let n = f.len();
    let c = f.c[0];
    let outer = outer_at(c, n);
    let mut f0 = f.clone();
    f0.c[0] = 0.0;
    outer.compose0(&f0)
}

/// Maclaurin coefficients (length n) of the depth-L ReLU NTK
/// K_relu^{(L)}(t) from the [ZHA+21] recursion — the Fig.-1 "Taylor"
/// baseline at d = infinity.
pub fn ntk_maclaurin(depth: usize, n: usize) -> Series {
    // sigma = theta = t
    let mut sigma = Series::identity(n);
    let mut theta = Series::identity(n);
    for _ in 0..depth.saturating_sub(1) {
        let a1s = compose_shifted(a1_series, &sigma);
        let a0s = compose_shifted(a0_series, &sigma);
        theta = a1s.add(&theta.mul(&a0s));
        sigma = compose_shifted(a1_series, &sigma);
    }
    theta
}

/// Maclaurin series of exp(a x).
pub fn exp_maclaurin(a: f64, n: usize) -> Series {
    let mut c = vec![0.0; n];
    c[0] = 1.0;
    for k in 1..n {
        c[k] = c[k - 1] * a / k as f64;
    }
    Series { c }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{arccos_a0, arccos_a1, ntk_kappa};

    #[test]
    fn binomial_matches_function() {
        let s = binomial_series(0.5, 0.3, 20);
        for &u in &[-0.5f64, -0.1, 0.2, 0.8] {
            let exact = (1.0 + 0.3 * u).powf(0.5);
            assert!((s.eval(u) - exact).abs() < 1e-10, "u={u}");
        }
    }

    #[test]
    fn acos_series_matches() {
        for &x0 in &[0.0, 0.3, -0.4, 0.318] {
            let s = acos_series(x0, 24);
            for &u in &[-0.1, 0.0, 0.05, 0.15] {
                let exact = (x0 + u).acos();
                assert!((s.eval(u) - exact).abs() < 1e-9, "x0={x0} u={u}");
            }
        }
    }

    #[test]
    fn a0_a1_series_match() {
        for &x0 in &[0.0, 0.25, -0.3] {
            let s0 = a0_series(x0, 24);
            let s1 = a1_series(x0, 24);
            for &u in &[-0.1, 0.08] {
                assert!((s0.eval(u) - arccos_a0(x0 + u)).abs() < 1e-9);
                assert!((s1.eval(u) - arccos_a1(x0 + u)).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn exp_series() {
        let s = exp_maclaurin(2.0, 30);
        for &t in &[-1.0, -0.2, 0.5, 1.0] {
            assert!((s.eval(t) - (2.0 * t).exp()).abs() < 1e-9, "t={t}");
        }
    }

    #[test]
    fn ntk_maclaurin_matches_function_near_zero() {
        // inside the radius of convergence the truncated Maclaurin series
        // must match the NTK recursion
        for depth in [2usize, 3] {
            let s = ntk_maclaurin(depth, 24);
            for &t in &[-0.3, -0.1, 0.0, 0.2, 0.4] {
                let exact = ntk_kappa(t, depth);
                assert!(
                    (s.eval(t) - exact).abs() < 2e-5,
                    "depth={depth} t={t}: {} vs {exact}",
                    s.eval(t)
                );
            }
        }
    }

    #[test]
    fn ntk_maclaurin_value_at_zero() {
        // one recursion step (depth 2): K(0) = a1(0) + 0 * a0(0) = 1/pi
        let s2 = ntk_maclaurin(2, 10);
        assert!((s2.c[0] - 1.0 / std::f64::consts::PI).abs() < 1e-12);
        // two steps (depth 3, the Fig.-1 formula):
        // K(0) = a1(a1(0)) + (a1(0) + 0) a0(a1(0))
        let s3 = ntk_maclaurin(3, 10);
        let c = 1.0 / std::f64::consts::PI;
        let expect = arccos_a1(c) + c * arccos_a0(c);
        assert!((s3.c[0] - expect).abs() < 1e-12);
    }

    #[test]
    fn compose0_matches_direct() {
        // exp(2 * sin-like polynomial)
        let mut g = Series::zero(16);
        g.c[1] = 1.0;
        g.c[3] = -1.0 / 6.0;
        let e = exp_maclaurin(1.0, 16);
        let comp = e.compose0(&g);
        for &t in &[-0.4, 0.1, 0.3] {
            let gval = t - t * t * t / 6.0;
            assert!((comp.eval(t) - gval.exp()).abs() < 1e-6, "t={t}");
        }
    }
}
