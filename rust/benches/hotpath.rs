//! Bench: hot-path microbenchmarks for the §Perf pass — featurization
//! throughput for every method in the registry, the native Gegenbauer
//! config sweep vs a pure-matmul roofline of equal flop count, the
//! microkernel GFLOP/s section (every hot linalg kernel vs its frozen
//! pre-microkernel counterpart, bit-identity asserted) with the MR×NR×KC
//! tile-geometry sweep, plus the serving batcher's latency under load.
//!
//! Besides the human-readable tables, the run emits a machine-readable
//! `BENCH_hotpath.json` (format 5, path overridable via `GZK_BENCH_JSON`)
//! with the per-method throughput rows, the per-kernel GFLOP/s rows
//! (naive vs microkernel, speedup ≥2x asserted for matmul/syrk) and the
//! tile sweep (the run fails if the compiled-in default geometry is more
//! than 10% behind the sweep winner), the serial-vs-parallel
//! featurize+absorb comparison (threads, speedup, bit-identity check),
//! the streamed-vs-materialized ridge fit comparison (throughput + peak
//! feature-scratch bytes: the out-of-core pipeline's memory claim as a
//! number), the observability-overhead comparison (the chunked fit with
//! the metrics registry disabled vs enabled — the obs layer's "read-only
//! and nearly free" claim as a number), and the batcher latency
//! percentiles, so the perf trajectory is tracked across PRs instead of
//! scraped from stdout — CI uploads the file as a build artifact. The
//! pool width comes from `--threads`-equivalent `GZK_THREADS` or the
//! machine.
//!
//! A second artifact, `BENCH_serve.json` (loadgen format 5), records the
//! serve-path tracing overhead: the same socket loadgen run untraced vs
//! traced (per-request trace-ID minting + serve/loadgen span recording),
//! p50s compared under a 10% alarm bound and stored in the report's
//! `trace_overhead` section. That section runs last — `trace::enable()`
//! is process-global with no off switch, so it must not leak span
//! recording into the other sections' timings.
//!
//! Run: cargo bench --bench hotpath

use gzk::bench::{fmt_secs, time_it, Table};
use gzk::coordinator::PredictionService;
use gzk::data::{pipeline, DataSource, SyntheticSource};
use gzk::exec::Pool;
use gzk::features::{FeatureSpec, Featurizer, KernelSpec, Method};
use gzk::krr::{FeatureRidge, RidgeStats};
use gzk::linalg::microkernel::{self, matmul_with_tile, naive};
use gzk::linalg::Mat;
use gzk::model::{set_run_data, ModelStore, RidgeModel};
use gzk::rng::Rng;
use gzk::server::loadgen::{self, TraceOverhead};
use gzk::server::{LoadgenConfig, Server, ServerConfig};
use std::time::Duration;

fn gaussian() -> KernelSpec {
    KernelSpec::Gaussian { bandwidth: 1.0 }
}

struct MethodRow {
    method: &'static str,
    f_dim: usize,
    rows_per_s: f64,
    secs_per_call: f64,
}

struct ServingStats {
    req_per_s: f64,
    p50_us: f64,
    p99_us: f64,
    batches: usize,
    max_batch: usize,
}

/// Every registered method at one budget — a newly registered featurizer
/// shows up here with no bench changes.
fn registry_bench() -> Vec<MethodRow> {
    println!("== featurize throughput, every registered method ==");
    let (d, n, budget) = (3usize, 2048usize, 512usize);
    let mut rng = Rng::new(2);
    let x = Mat::from_fn(n, d, |_, _| rng.normal() * 0.5);
    let mut t = Table::new(vec!["method", "F", "rows/s", "Mfeat/s", "time/call"]);
    let mut rows = Vec::new();
    for method in Method::registry() {
        let spec = FeatureSpec::new(gaussian(), method.tuned(12, 2), budget, 1);
        let feat = spec.build_with_data(&x);
        // 3 warmup calls: one is not enough to fault in the feature
        // scratch and settle the frequency governor, and a cold first
        // timed rep skews a 5-rep median
        let timing = time_it(3, 5, || feat.featurize(&x));
        let rows_per_s = n as f64 / timing.median;
        t.row(vec![
            feat.name().to_string(),
            feat.dim().to_string(),
            format!("{rows_per_s:.0}"),
            format!("{:.1}", rows_per_s * feat.dim() as f64 / 1e6),
            fmt_secs(timing.median),
        ]);
        rows.push(MethodRow {
            method: feat.name(),
            f_dim: feat.dim(),
            rows_per_s,
            secs_per_call: timing.median,
        });
    }
    t.print();
    rows
}

fn featurize_bench() {
    println!("\n== gegenbauer hot path (budget = directions x s) ==");
    let mut t = Table::new(vec!["config", "rows/s", "Mfeat/s", "time/call"]);
    for (d, q, s, budget, n) in [
        (3usize, 12usize, 2usize, 1024usize, 2048usize),
        (9, 8, 2, 1024, 2048),
        (42, 4, 1, 512, 1024),
    ] {
        let spec = FeatureSpec::new(gaussian(), Method::Gegenbauer { q, s }, budget, 1);
        let feat = spec.build(d);
        let mut rng = Rng::new(2);
        let x = Mat::from_fn(n, d, |_, _| rng.normal() * 0.5);
        let timing = time_it(1, 5, || feat.featurize(&x));
        let rows_per_s = n as f64 / timing.median;
        let feats_per_s = rows_per_s * feat.dim() as f64 / 1e6;
        t.row(vec![
            format!("d={d} q={q} s={s} F={}", feat.dim()),
            format!("{rows_per_s:.0}"),
            format!("{feats_per_s:.1}"),
            fmt_secs(timing.median),
        ]);
    }
    t.print();

    // roofline comparison: featurize vs equal-flop matmul
    // featurize flops ~= n * m * (d + 3q + 2qs); matmul (n x k)(k x m): 2nkm
    let (d, q, s, m, n) = (3usize, 12usize, 2usize, 512usize, 2048usize);
    let spec = FeatureSpec::new(gaussian(), Method::Gegenbauer { q, s }, m * s, 1);
    let feat = spec.build(d);
    let mut rng = Rng::new(3);
    let x = Mat::from_fn(n, d, |_, _| rng.normal() * 0.5);
    let tf = time_it(3, 5, || feat.featurize(&x));
    let flops_feat = (n * m * (d + 3 * q + 2 * q * s)) as f64;
    let k = (flops_feat / (2.0 * (n * m) as f64)).ceil() as usize;
    let a = Mat::from_fn(n, k, |_, _| rng.normal());
    let b = Mat::from_fn(k, m, |_, _| rng.normal());
    let tm = time_it(3, 5, || a.matmul(&b));
    println!(
        "\nroofline: featurize {} vs equal-flop matmul {} -> efficiency {:.2}x",
        fmt_secs(tf.median),
        fmt_secs(tm.median),
        tm.median / tf.median
    );
}

struct GflopRow {
    kernel: &'static str,
    shape: String,
    flops: f64,
    naive_secs: f64,
    micro_secs: f64,
    naive_gflops: f64,
    micro_gflops: f64,
    speedup: f64,
    bit_identical: bool,
}

/// One kernel of the GFLOP/s section: median-time the frozen pre-PR
/// kernel and the microkernel on the same operands, assert the outputs
/// bit-identical, and convert to GFLOP/s.
fn gflop_row<T: PartialEq>(
    kernel: &'static str,
    shape: String,
    flops: f64,
    old: impl Fn() -> T,
    new: impl Fn() -> T,
) -> GflopRow {
    let bit_identical = old() == new();
    assert!(bit_identical, "{kernel}: microkernel drifted from the pre-PR kernel");
    let tn = time_it(2, 3, &old);
    let tm = time_it(2, 3, &new);
    GflopRow {
        kernel,
        shape,
        flops,
        naive_secs: tn.median,
        micro_secs: tm.median,
        naive_gflops: flops / tn.median / 1e9,
        micro_gflops: flops / tm.median / 1e9,
        speedup: tn.median / tm.median,
        bit_identical,
    }
}

/// Every hot linalg kernel vs its frozen pre-microkernel counterpart at
/// the paper-scale shape (n = 8192, F = 512), in GFLOP/s. Bit-identity
/// is asserted per kernel — the speedup must come from scheduling the
/// same arithmetic, never from reassociating it — and serial ↔ parallel
/// identity is asserted on the real bench shapes. The ≥2x floor on
/// matmul/syrk is the PR's acceptance bar.
fn gflops_bench(pool: &Pool) -> Vec<GflopRow> {
    println!("\n== microkernel GFLOP/s vs pre-microkernel kernels (n=8192, F=512) ==");
    let (n, f) = (8192usize, 512usize);
    let mut rng = Rng::new(9);
    let a = Mat::from_fn(n, f, |_, _| rng.normal());
    let b = Mat::from_fn(f, f, |_, _| rng.normal());
    let a2 = a.row_block(0, 2048);
    let c2 = Mat::from_fn(2048, f, |_, _| rng.normal());
    let x: Vec<f64> = (0..f).map(|_| rng.normal()).collect();

    // serial ↔ parallel bit-identity on the bench shapes themselves
    assert!(a.matmul_p(&b, pool) == a.matmul(&b), "matmul parallel drifted from serial");
    let mut g_ser = Mat::zeros(f, f);
    a.syrk_into(&mut g_ser);
    let mut g_par = Mat::zeros(f, f);
    a.syrk_into_p(&mut g_par, pool);
    assert!(g_ser == g_par, "syrk parallel drifted from serial");

    let rows = vec![
        gflop_row(
            "matmul",
            format!("({n}x{f})*({f}x{f})"),
            2.0 * (n * f * f) as f64,
            || naive::matmul_p(&a, &b, pool),
            || a.matmul_p(&b, pool),
        ),
        gflop_row(
            "matmul_nt",
            format!("(2048x{f})*(2048x{f})^T"),
            2.0 * (2048 * 2048 * f) as f64,
            || naive::matmul_nt_p(&a2, &c2, pool),
            || a2.matmul_nt_p(&c2, pool),
        ),
        gflop_row(
            "matmul_tn",
            format!("({n}x{f})^T*({n}x{f})"),
            2.0 * (n * f * f) as f64,
            || naive::matmul_tn_p(&a, &a, pool),
            || a.matmul_tn_p(&a, pool),
        ),
        gflop_row(
            "syrk",
            format!("z^T z, z={n}x{f}"),
            (n * f * (f + 1)) as f64,
            || {
                let mut g = Mat::zeros(f, f);
                naive::syrk_flat_into_p(a.data(), f, &mut g, pool);
                g
            },
            || {
                let mut g = Mat::zeros(f, f);
                a.syrk_into_p(&mut g, pool);
                g
            },
        ),
        gflop_row(
            // serial on both sides: matvec is memory-bound and the row
            // should show the register-blocking win, not the pool width
            "matvec",
            format!("({n}x{f})*x serial"),
            2.0 * (n * f) as f64,
            || naive::matvec(&a, &x),
            || a.matvec(&x),
        ),
    ];

    let mut t = Table::new(vec!["kernel", "shape", "old GF/s", "new GF/s", "speedup"]);
    for r in &rows {
        t.row(vec![
            r.kernel.to_string(),
            r.shape.clone(),
            format!("{:.2}", r.naive_gflops),
            format!("{:.2}", r.micro_gflops),
            format!("{:.2}x", r.speedup),
        ]);
    }
    t.print();
    for r in &rows {
        if r.kernel == "matmul" || r.kernel == "syrk" {
            assert!(
                r.speedup >= 2.0,
                "{}: microkernel speedup {:.2}x is below the 2x acceptance floor",
                r.kernel,
                r.speedup
            );
        }
    }
    rows
}

struct TileSweepRow {
    mr: usize,
    nr: usize,
    kc: usize,
    secs: f64,
    gflops: f64,
    is_default: bool,
}

fn matmul_tiled(mr: usize, nr: usize, a: &Mat, b: &Mat, kc: usize, pool: &Pool) -> Mat {
    match (mr, nr) {
        (4, 4) => matmul_with_tile::<4, 4>(a, b, kc, pool),
        (8, 4) => matmul_with_tile::<8, 4>(a, b, kc, pool),
        (8, 8) => matmul_with_tile::<8, 8>(a, b, kc, pool),
        _ => unreachable!("unswept tile geometry {mr}x{nr}"),
    }
}

/// Sweep the register-tile geometry (MR×NR) and the k cache depth (KC)
/// over matmul at n = 4096, F = 512, asserting every geometry produces
/// the exact default-path bits, and fail the run if the compiled-in
/// default is more than 10% behind the sweep winner — the default must
/// be re-tuned, not merely documented, when hardware moves.
fn tile_sweep_bench(pool: &Pool) -> Vec<TileSweepRow> {
    println!("\n== tile-geometry sweep: matmul (n=4096, F=512) ==");
    let (n, f) = (4096usize, 512usize);
    let mut rng = Rng::new(10);
    let a = Mat::from_fn(n, f, |_, _| rng.normal());
    let b = Mat::from_fn(f, f, |_, _| rng.normal());
    let want = a.matmul_p(&b, pool);
    let flops = 2.0 * (n * f * f) as f64;
    let mut rows = Vec::new();
    let mut t = Table::new(vec!["tile", "kc", "GF/s", "time/call"]);
    for (mr, nr) in [(4usize, 4usize), (8, 4), (8, 8)] {
        for kc in [128usize, 256, 512] {
            let got = matmul_tiled(mr, nr, &a, &b, kc, pool);
            assert!(got == want, "{mr}x{nr} kc={kc} drifted from the default path");
            let timing = time_it(1, 3, || matmul_tiled(mr, nr, &a, &b, kc, pool));
            let gflops = flops / timing.median / 1e9;
            let is_default =
                (mr, nr, kc) == (microkernel::MR, microkernel::NR, microkernel::KC);
            t.row(vec![
                format!("{mr}x{nr}"),
                kc.to_string(),
                format!("{gflops:.2}"),
                fmt_secs(timing.median),
            ]);
            rows.push(TileSweepRow { mr, nr, kc, secs: timing.median, gflops, is_default });
        }
    }
    t.print();
    let best = rows.iter().map(|r| r.gflops).fold(0.0, f64::max);
    let default =
        rows.iter().find(|r| r.is_default).expect("default geometry missing from sweep");
    println!(
        "default {}x{} kc={} at {:.2} GF/s vs sweep winner {best:.2} GF/s",
        default.mr, default.nr, default.kc, default.gflops
    );
    assert!(
        default.gflops >= 0.90 * best,
        "default tile {}x{} kc={} ({:.2} GF/s) is >10% behind the sweep winner ({best:.2} GF/s)",
        default.mr,
        default.nr,
        default.kc,
        default.gflops
    );
    rows
}

struct ParallelStats {
    threads: usize,
    serial_secs: f64,
    par_secs: f64,
    speedup: f64,
    bit_identical: bool,
}

/// Serial vs parallel on the training hot path — featurize + absorb at
/// n = 8192, m = 512 — with the outputs cross-checked for bit-identity
/// (the exec engine's core contract).
fn parallel_bench() -> ParallelStats {
    println!("\n== serial vs parallel: featurize + absorb (n=8192, m=512) ==");
    let (n, d) = (8192usize, 3usize);
    let spec = FeatureSpec::new(gaussian(), Method::Gegenbauer { q: 12, s: 2 }, 512, 1);
    let feat = spec.build(d);
    let mut rng = Rng::new(5);
    let x = Mat::from_fn(n, d, |_, _| rng.normal() * 0.5);
    let y: Vec<f64> = (0..n).map(|i| x[(i, 0)]).collect();
    let run = |pool: &Pool| {
        let z = feat.featurize_par(&x, pool);
        let mut stats = RidgeStats::new(z.cols());
        stats.absorb_with(&z, &y, pool);
        (z, stats)
    };
    let serial = Pool::serial();
    let par = Pool::global();
    let ts = time_it(1, 3, || run(&serial));
    let tp = time_it(1, 3, || run(&par));
    let (zs, ss) = run(&serial);
    let (zp, sp) = run(&par);
    let bit_identical = zs == zp && ss.g == sp.g && ss.b == sp.b;
    let speedup = ts.median / tp.median;
    println!(
        "threads {}: serial {}  parallel {}  -> {speedup:.2}x speedup (bit identical: {bit_identical})",
        par.threads(),
        fmt_secs(ts.median),
        fmt_secs(tp.median)
    );
    assert!(bit_identical, "parallel featurize+absorb drifted from serial");
    ParallelStats {
        threads: par.threads(),
        serial_secs: ts.median,
        par_secs: tp.median,
        speedup,
        bit_identical,
    }
}

struct StreamingStats {
    n: usize,
    m: usize,
    chunk_rows: usize,
    streamed_secs: f64,
    materialized_secs: f64,
    streamed_rows_per_s: f64,
    materialized_rows_per_s: f64,
    /// peak feature-matrix allocation of each path, in bytes
    streamed_peak_z_bytes: usize,
    materialized_peak_z_bytes: usize,
    bit_identical: bool,
}

/// Streamed (chunked DataSource pipeline) vs materialized (full n x m
/// feature matrix) ridge fit at n = 65,536, m = 512. Same sufficient
/// statistics bit for bit; the streamed path's peak feature allocation is
/// `chunk_rows x m x 8` bytes instead of `n x m x 8` — the out-of-core
/// claim, reported as numbers.
fn streaming_bench() -> StreamingStats {
    println!("\n== streamed vs materialized ridge fit (n=65536, m=512) ==");
    let (n, m, chunk_rows) = (65_536usize, 512usize, 4096usize);
    let src = SyntheticSource::elevation(n, 3);
    let spec = FeatureSpec::new(gaussian(), Method::Gegenbauer { q: 12, s: 2 }, m, 1);
    let feat = spec.build(3);
    let pool = Pool::global();

    let t_stream = time_it(0, 2, || {
        pipeline::ridge_stats(feat.as_ref(), &src, chunk_rows, &pool).expect("streamed fit")
    });
    let (streamed, sinfo) =
        pipeline::ridge_stats(feat.as_ref(), &src, chunk_rows, &pool).expect("streamed fit");

    // materialized reference: read everything, featurize everything, absorb
    let t_mat = time_it(0, 2, || {
        let (x, y) = src.read_range(0, n).expect("in-memory read");
        let z = feat.featurize_par(&x, &pool);
        let mut stats = RidgeStats::new(z.cols());
        stats.absorb_with(&z, &y, &pool);
        stats
    });
    let (x, y) = src.read_range(0, n).expect("in-memory read");
    let z = feat.featurize_par(&x, &pool);
    let mut materialized = RidgeStats::new(z.cols());
    materialized.absorb_with(&z, &y, &pool);
    let materialized_peak = n * feat.dim() * 8;

    let bit_identical = streamed.g == materialized.g
        && streamed.b == materialized.b
        && streamed.n == materialized.n;
    assert!(bit_identical, "streamed fit drifted from the materialized fit");
    let stats = StreamingStats {
        n,
        m: feat.dim(),
        chunk_rows,
        streamed_secs: t_stream.median,
        materialized_secs: t_mat.median,
        streamed_rows_per_s: n as f64 / t_stream.median,
        materialized_rows_per_s: n as f64 / t_mat.median,
        streamed_peak_z_bytes: sinfo.peak_z_bytes,
        materialized_peak_z_bytes: materialized_peak,
        bit_identical,
    };
    println!(
        "streamed    {}  ({:.0} rows/s, peak Z {:.1} MiB)",
        fmt_secs(stats.streamed_secs),
        stats.streamed_rows_per_s,
        stats.streamed_peak_z_bytes as f64 / (1 << 20) as f64
    );
    println!(
        "materialized {}  ({:.0} rows/s, peak Z {:.1} MiB)  bit identical: {}",
        fmt_secs(stats.materialized_secs),
        stats.materialized_rows_per_s,
        stats.materialized_peak_z_bytes as f64 / (1 << 20) as f64,
        stats.bit_identical
    );
    stats
}

struct ObsOverheadStats {
    disabled_secs: f64,
    enabled_secs: f64,
    /// (enabled - disabled) / disabled, in percent; can be slightly
    /// negative from run-to-run noise
    overhead_pct: f64,
    bit_identical: bool,
}

/// The obs layer's cost on the training hot path: the chunked
/// featurize+absorb fit (n = 8192, m = 512 — the instrumented
/// `pipeline::ridge_stats` loop with its per-chunk spans and counters)
/// timed with the metrics registry disabled vs enabled. The contract is
/// "observability is read-only and nearly free": same bits out, and the
/// instrumented run within a couple percent of the bare one. The
/// assertion bound is a loose 10% (shared-CI timer noise); the JSON
/// records the real number so the trajectory is tracked across PRs.
fn obs_overhead_bench() -> ObsOverheadStats {
    println!("\n== observability overhead: chunked fit, registry off vs on (n=8192, m=512) ==");
    let (n, chunk_rows) = (8192usize, 1024usize);
    let src = SyntheticSource::elevation(n, 3);
    let spec = FeatureSpec::new(gaussian(), Method::Gegenbauer { q: 12, s: 2 }, 512, 1);
    let feat = spec.build(3);
    let pool = Pool::global();
    let run = || {
        pipeline::ridge_stats(feat.as_ref(), &src, chunk_rows, &pool).expect("chunked fit").0
    };

    gzk::obs::registry::set_enabled(false);
    let t_off = time_it(1, 3, run);
    let stats_off = run();
    gzk::obs::registry::set_enabled(true);
    let t_on = time_it(1, 3, run);
    let stats_on = run();

    let bit_identical =
        stats_off.g == stats_on.g && stats_off.b == stats_on.b && stats_off.n == stats_on.n;
    assert!(bit_identical, "enabling the metrics registry changed the fit");
    let overhead_pct = (t_on.median - t_off.median) / t_off.median * 100.0;
    println!(
        "registry off {}  on {}  -> overhead {overhead_pct:+.2}% (bit identical: {bit_identical})",
        fmt_secs(t_off.median),
        fmt_secs(t_on.median)
    );
    assert!(
        overhead_pct < 10.0,
        "observability overhead {overhead_pct:.2}% blew through the 10% alarm bound"
    );
    ObsOverheadStats {
        disabled_secs: t_off.median,
        enabled_secs: t_on.median,
        overhead_pct,
        bit_identical,
    }
}

fn serving_bench() -> ServingStats {
    println!("\n== serving batcher ==");
    let spec = FeatureSpec::new(gaussian(), Method::Gegenbauer { q: 12, s: 2 }, 512, 1).bind(3);
    let mut rng = Rng::new(4);
    let x = Mat::from_fn(512, 3, |_, _| rng.normal() * 0.5);
    let y: Vec<f64> = (0..512).map(|i| x[(i, 0)]).collect();
    let z = spec.build().featurize(&x);
    let model = FeatureRidge::fit(&z, &y, 1e-3);
    let svc = PredictionService::start(spec, model, 64, Duration::ZERO).expect("start service");
    let client = svc.client();
    let _ = client.predict(x.row(0));
    let n_req = 5000;
    let t0 = std::time::Instant::now();
    let mut lat = Vec::with_capacity(n_req);
    for i in 0..n_req {
        let t = std::time::Instant::now();
        let _ = client.predict(x.row(i % 512)).unwrap();
        lat.push(t.elapsed().as_secs_f64());
    }
    let wall = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = lat[n_req / 2];
    let p99 = lat[n_req * 99 / 100];
    println!(
        "sequential client: {:.0} req/s, p50 {} p99 {}",
        n_req as f64 / wall,
        fmt_secs(p50),
        fmt_secs(p99)
    );
    let m = svc.metrics();
    println!("batches {} (max batch {})", m.batches, m.max_batch_seen);
    ServingStats {
        req_per_s: n_req as f64 / wall,
        p50_us: p50 * 1e6,
        p99_us: p99 * 1e6,
        batches: m.batches,
        max_batch: m.max_batch_seen,
    }
}

/// Serve-path tracing overhead, written to `BENCH_serve.json` (loadgen
/// format 5): the same socket loadgen trial against an in-process
/// server, untraced vs traced — the traced pass mints a trace ID per
/// request and records serve/loadgen spans. MUST run after every other
/// section: `trace::enable()` is process-global with no off switch, so
/// span recording would otherwise leak into their timings.
fn serve_trace_overhead_bench() {
    println!("\n== serve tracing overhead: loadgen untraced vs traced (4 clients) ==");
    let dir = std::env::temp_dir().join(format!("gzk-bench-serve-trace-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let spec = FeatureSpec::new(gaussian(), Method::Gegenbauer { q: 5, s: 1 }, 64, 11).bind(3);
    let mut rng = Rng::new(0xBEEF);
    let x = Mat::from_fn(256, 3, |_, _| rng.normal() * 0.5);
    let y: Vec<f64> = (0..256).map(|i| x[(i, 0)] + 0.3 * x[(i, 2)]).collect();
    let model = RidgeModel::fit(spec, &x, &y, 1e-3).expect("fit serve model");
    set_run_data("elevation", 256);
    ModelStore::open(&dir).expect("open store").save("ridge", &model).expect("save model");

    let server =
        Server::start(&dir, "127.0.0.1:0", ServerConfig::default()).expect("start server");
    let cfg = |traced: bool| LoadgenConfig {
        addr: server.local_addr().to_string(),
        clients: vec![4],
        requests_per_client: 300,
        dataset: Some("elevation".to_string()),
        store: Some(dir.clone()),
        traced,
        ..LoadgenConfig::default()
    };
    // warm-up trial: connection setup, page cache, the admission ladder
    loadgen::run(&cfg(false)).expect("warm-up loadgen");
    let off = loadgen::run(&cfg(false)).expect("untraced loadgen");
    gzk::obs::trace::enable();
    let mut on = loadgen::run(&cfg(true)).expect("traced loadgen");
    server.shutdown();
    let _ = server.wait();
    let _ = std::fs::remove_dir_all(&dir);

    // both passes bit-verify against the local model twin: tracing is
    // read-only on the serve path
    assert_eq!(off.mismatches(), 0, "untraced replies drifted from the local model");
    assert_eq!(on.mismatches(), 0, "traced replies drifted from the local model");
    let (p50_us_off, p50_us_on) = (off.trials[0].p50_us, on.trials[0].p50_us);
    let delta_us = p50_us_on - p50_us_off;
    let overhead_frac = delta_us / p50_us_off;
    println!(
        "p50 untraced {p50_us_off:.1}us  traced {p50_us_on:.1}us  -> overhead {:+.2}%",
        overhead_frac * 100.0
    );
    // 10% alarm bound, with a 25us absolute floor so loopback scheduling
    // jitter on a microsecond-scale p50 cannot trip it
    assert!(
        overhead_frac < 0.10 || delta_us < 25.0,
        "serve tracing overhead {:.2}% ({delta_us:.1}us) blew through the 10% alarm bound",
        overhead_frac * 100.0
    );
    on.trace_overhead = Some(TraceOverhead { p50_us_off, p50_us_on, overhead_frac });
    let path = std::env::var("GZK_SERVE_JSON").unwrap_or_else(|_| "BENCH_serve.json".to_string());
    on.write_json(std::path::Path::new(&path)).expect("write serve bench json");
    println!("wrote {path}");
}

/// Emit the machine-readable results (CI uploads this as an artifact).
fn write_json(
    methods: &[MethodRow],
    gflops: &[GflopRow],
    tiles: &[TileSweepRow],
    parallel: &ParallelStats,
    streaming: &StreamingStats,
    obs: &ObsOverheadStats,
    serving: &ServingStats,
) {
    let path =
        std::env::var("GZK_BENCH_JSON").unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    let method_rows: Vec<String> = methods
        .iter()
        .map(|r| {
            format!(
                r#"{{"method":"{}","f":{},"rows_per_s":{:.1},"secs_per_call":{:e}}}"#,
                r.method, r.f_dim, r.rows_per_s, r.secs_per_call
            )
        })
        .collect();
    let gflop_rows: Vec<String> = gflops
        .iter()
        .map(|r| {
            format!(
                concat!(
                    r#"{{"kernel":"{}","shape":"{}","flops":{:e},"#,
                    r#""naive_secs":{:e},"micro_secs":{:e},"#,
                    r#""naive_gflops":{:.2},"micro_gflops":{:.2},"#,
                    r#""speedup":{:.2},"bit_identical":{}}}"#
                ),
                r.kernel,
                r.shape,
                r.flops,
                r.naive_secs,
                r.micro_secs,
                r.naive_gflops,
                r.micro_gflops,
                r.speedup,
                r.bit_identical
            )
        })
        .collect();
    let tile_rows: Vec<String> = tiles
        .iter()
        .map(|r| {
            format!(
                r#"{{"mr":{},"nr":{},"kc":{},"secs":{:e},"gflops":{:.2},"default":{}}}"#,
                r.mr, r.nr, r.kc, r.secs, r.gflops, r.is_default
            )
        })
        .collect();
    let winner_gflops = tiles.iter().map(|r| r.gflops).fold(0.0, f64::max);
    let default_gflops =
        tiles.iter().find(|r| r.is_default).map(|r| r.gflops).unwrap_or(0.0);
    let text = format!(
        concat!(
            r#"{{"format":5,"bench":"hotpath","methods":[{}],"#,
            r#""gflops":[{}],"#,
            r#""tile_sweep":{{"rows":[{}],"default_gflops":{:.2},"winner_gflops":{:.2}}},"#,
            r#""parallel":{{"threads":{},"serial_secs":{:e},"par_secs":{:e},"speedup":{:.2},"bit_identical":{}}},"#,
            r#""streaming":{{"n":{},"m":{},"chunk_rows":{},"streamed_secs":{:e},"materialized_secs":{:e},"#,
            r#""streamed_rows_per_s":{:.1},"materialized_rows_per_s":{:.1},"#,
            r#""streamed_peak_z_bytes":{},"materialized_peak_z_bytes":{},"bit_identical":{}}},"#,
            r#""obs_overhead":{{"disabled_secs":{:e},"enabled_secs":{:e},"overhead_pct":{:.2},"bit_identical":{}}},"#,
            r#""serving":{{"req_per_s":{:.1},"p50_us":{:.2},"p99_us":{:.2},"batches":{},"max_batch":{}}}}}"#
        ),
        method_rows.join(","),
        gflop_rows.join(","),
        tile_rows.join(","),
        default_gflops,
        winner_gflops,
        parallel.threads,
        parallel.serial_secs,
        parallel.par_secs,
        parallel.speedup,
        parallel.bit_identical,
        streaming.n,
        streaming.m,
        streaming.chunk_rows,
        streaming.streamed_secs,
        streaming.materialized_secs,
        streaming.streamed_rows_per_s,
        streaming.materialized_rows_per_s,
        streaming.streamed_peak_z_bytes,
        streaming.materialized_peak_z_bytes,
        streaming.bit_identical,
        obs.disabled_secs,
        obs.enabled_secs,
        obs.overhead_pct,
        obs.bit_identical,
        serving.req_per_s,
        serving.p50_us,
        serving.p99_us,
        serving.batches,
        serving.max_batch
    );
    std::fs::write(&path, text).unwrap_or_else(|e| panic!("write {path}: {e}"));
    println!("\nwrote {path}");
}

fn main() {
    let methods = registry_bench();
    featurize_bench();
    let pool = Pool::global();
    let gflops = gflops_bench(&pool);
    let tiles = tile_sweep_bench(&pool);
    let parallel = parallel_bench();
    let streaming = streaming_bench();
    let obs = obs_overhead_bench();
    let serving = serving_bench();
    write_json(&methods, &gflops, &tiles, &parallel, &streaming, &obs, &serving);
    // last on purpose: enables process-global tracing (see its doc)
    serve_trace_overhead_bench();
}
