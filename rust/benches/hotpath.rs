//! Bench: hot-path microbenchmarks for the §Perf pass — native Gegenbauer
//! featurization throughput vs a pure-matmul roofline of equal flop count,
//! plus the serving batcher's latency under load.
//! Run: cargo bench --bench hotpath

use gzk::bench::{fmt_secs, time_it, Table};
use gzk::coordinator::{Family, FeatureSpec, PredictionService};
use gzk::features::{Featurizer, GegenbauerFeatures, RadialTable};
use gzk::krr::FeatureRidge;
use gzk::linalg::Mat;
use gzk::rng::Rng;
use std::time::Duration;

fn featurize_bench() {
    println!("== featurize hot path ==");
    let mut t = Table::new(vec!["config", "rows/s", "Mfeat/s", "time/call"]);
    for (d, q, s, m, n) in [(3usize, 12usize, 2usize, 512usize, 2048usize), (9, 8, 2, 512, 2048), (42, 4, 1, 512, 1024)] {
        let table = RadialTable::gaussian(d, q, s);
        let feat = GegenbauerFeatures::new(table, m, 1);
        let mut rng = Rng::new(2);
        let x = Mat::from_fn(n, d, |_, _| rng.normal() * 0.5);
        let timing = time_it(1, 5, || feat.featurize(&x));
        let rows_per_s = n as f64 / timing.median;
        let feats_per_s = rows_per_s * (m * s) as f64 / 1e6;
        t.row(vec![
            format!("d={d} q={q} s={s} m={m}"),
            format!("{rows_per_s:.0}"),
            format!("{feats_per_s:.1}"),
            fmt_secs(timing.median),
        ]);
    }
    t.print();

    // roofline comparison: featurize vs equal-flop matmul
    // featurize flops ~= n * m * (d + 3q + 2qs); matmul (n x k)(k x m): 2nkm
    let (d, q, s, m, n) = (3usize, 12usize, 2usize, 512usize, 2048usize);
    let feat = GegenbauerFeatures::new(RadialTable::gaussian(d, q, s), m, 1);
    let mut rng = Rng::new(3);
    let x = Mat::from_fn(n, d, |_, _| rng.normal() * 0.5);
    let tf = time_it(1, 5, || feat.featurize(&x));
    let flops_feat = (n * m * (d + 3 * q + 2 * q * s)) as f64;
    let k = (flops_feat / (2.0 * (n * m) as f64)).ceil() as usize;
    let a = Mat::from_fn(n, k, |_, _| rng.normal());
    let b = Mat::from_fn(k, m, |_, _| rng.normal());
    let tm = time_it(1, 5, || a.matmul(&b));
    println!(
        "\nroofline: featurize {} vs equal-flop matmul {} -> efficiency {:.2}x",
        fmt_secs(tf.median),
        fmt_secs(tm.median),
        tm.median / tf.median
    );
}

fn serving_bench() {
    println!("\n== serving batcher ==");
    let spec = FeatureSpec {
        family: Family::Gaussian { bandwidth: 1.0 },
        d: 3,
        q: 12,
        s: 2,
        m: 256,
        seed: 1,
    };
    let mut rng = Rng::new(4);
    let x = Mat::from_fn(512, 3, |_, _| rng.normal() * 0.5);
    let y: Vec<f64> = (0..512).map(|i| x[(i, 0)]).collect();
    let z = spec.build().featurize(&x);
    let model = FeatureRidge::fit(&z, &y, 1e-3);
    let svc = PredictionService::start(spec, model, 64, Duration::ZERO);
    let client = svc.client();
    let _ = client.predict(x.row(0));
    let n_req = 5000;
    let t0 = std::time::Instant::now();
    let mut lat = Vec::with_capacity(n_req);
    for i in 0..n_req {
        let t = std::time::Instant::now();
        let _ = client.predict(x.row(i % 512)).unwrap();
        lat.push(t.elapsed().as_secs_f64());
    }
    let wall = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "sequential client: {:.0} req/s, p50 {} p99 {}",
        n_req as f64 / wall,
        fmt_secs(lat[n_req / 2]),
        fmt_secs(lat[n_req * 99 / 100])
    );
    let m = svc.metrics();
    println!("batches {} (max batch {})", m.batches, m.max_batch_seen);
}

fn main() {
    featurize_bench();
    serving_bench();
}
