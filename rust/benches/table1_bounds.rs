//! Bench: regenerate Table 1 — feature-dimension bounds per method plus an
//! empirical features-needed-for-eps sweep.
//! Run: cargo bench --bench table1_bounds

use gzk::experiments::table1;

fn main() {
    let rows = table1::run_bounds();
    table1::print_bounds(&rows);

    let (n, d, lam) = (64usize, 3usize, 0.5f64);
    println!("\nempirical sweep on n={n} d={d} lambda={lam}:");
    let emp = table1::run_empirical(n, d, lam, 0.5, 1);
    table1::print_empirical(&emp, 0.5);
}
