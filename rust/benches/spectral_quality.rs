//! Bench: Eq.-1 spectral-approximation quality vs feature count (the
//! empirical companion of Theorems 9/12).
//! Run: cargo bench --bench spectral_quality

use gzk::experiments::spectral_quality;

fn main() {
    let (s_lambda, rows) = spectral_quality::run(96, 3, 0.1, 1);
    spectral_quality::print(s_lambda, &rows);
}
