//! Bench: regenerate Figure 1 (function approximation error of Taylor vs
//! Chebyshev vs Gegenbauer series, degree <= 15).
//! Run: cargo bench --bench fig1_series

use gzk::bench::time_it;
use gzk::experiments::fig1;

fn main() {
    let t = time_it(0, 1, || fig1::run(15));
    let curves = fig1::run(15);
    fig1::print(&curves);
    println!("\n[fig1] computed in {}", t.pretty());

    // headline checks mirrored from the paper's discussion
    let exp = &curves[0];
    println!(
        "[fig1] exp(2x) degree-15:  taylor {:.2e}  cheb(d=2) {:.2e}  d=4 {:.2e}  d=8 {:.2e}  d=32 {:.2e}",
        exp.taylor[15],
        exp.gegenbauer[(0, 15)],
        exp.gegenbauer[(1, 15)],
        exp.gegenbauer[(2, 15)],
        exp.gegenbauer[(3, 15)]
    );
    assert!(exp.gegenbauer[(0, 15)] < exp.taylor[15], "Chebyshev must beat Taylor");
}
