//! Ablation bench: the design choices behind the Gegenbauer features —
//! truncation degree q, radial order s, and feature budget m — swept
//! independently on the elevation workload, plus a registry-wide method
//! comparison at a fixed budget. This is the empirical face of Theorems
//! 11/12: q and s control truncation BIAS, m controls VARIANCE.
//!
//! All featurizers are built through `FeatureSpec`, so the final table
//! automatically covers any newly registered method.
//!
//! Run: cargo bench --bench ablation

use gzk::bench::Table;
use gzk::data;
use gzk::features::{FeatureSpec, Featurizer, KernelSpec, Method};
use gzk::kernels::Kernel;
use gzk::krr::{mse, FeatureRidge};
use gzk::linalg::Mat;
use gzk::rng::Rng;
use gzk::spectral::spectral_epsilon;

fn gaussian() -> KernelSpec {
    KernelSpec::Gaussian { bandwidth: 1.0 }
}

fn elevation_task(n: usize) -> (Mat, Vec<f64>, Mat, Vec<f64>) {
    let ds = data::elevation(n, 3);
    data::split(&ds.x, &ds.y, 0.2, 3)
}

fn spec_mse(
    spec: &FeatureSpec,
    xtr: &Mat,
    ytr: &[f64],
    xte: &Mat,
    yte: &[f64],
) -> f64 {
    let feat = spec.build_with_data(xtr);
    let ztr = feat.featurize(xtr);
    let zte = feat.featurize(xte);
    let model = FeatureRidge::fit(&ztr, ytr, 1e-2 * ytr.len() as f64 / 1000.0);
    mse(&model.predict(&zte), yte)
}

fn krr_mse(q: usize, s: usize, m: usize, xtr: &Mat, ytr: &[f64], xte: &Mat, yte: &[f64]) -> f64 {
    let spec = FeatureSpec::new(gaussian(), Method::Gegenbauer { q, s }, m, 7);
    spec_mse(&spec, xtr, ytr, xte, yte)
}

fn main() {
    let (xtr, ytr, xte, yte) = elevation_task(6000);

    println!("== ablation: truncation degree q (s = 2, m = 512) ==");
    let mut t = Table::new(vec!["q", "test mse"]);
    for q in [2usize, 4, 6, 8, 12, 16] {
        t.row(vec![q.to_string(), format!("{:.4}", krr_mse(q, 2, 512, &xtr, &ytr, &xte, &yte))]);
    }
    t.print();

    println!("\n== ablation: radial order s (q = 12, m = 512) ==");
    let mut t = Table::new(vec!["s", "test mse"]);
    for s in [1usize, 2, 3, 4] {
        t.row(vec![s.to_string(), format!("{:.4}", krr_mse(12, s, 512, &xtr, &ytr, &xte, &yte))]);
    }
    t.print();

    println!("\n== ablation: feature budget m (q = 12, s = 2) ==");
    let mut t = Table::new(vec!["features", "test mse"]);
    for m in [64usize, 128, 256, 512, 1024, 2048] {
        t.row(vec![m.to_string(), format!("{:.4}", krr_mse(12, 2, m, &xtr, &ytr, &xte, &yte))]);
    }
    t.print();

    // spectral eps vs (q, s) at fixed m — truncation bias floor
    println!("\n== ablation: eps (Eq. 1) vs truncation at m = 4096, lambda = 0.1 ==");
    let mut rng = Rng::new(9);
    let x = Mat::from_fn(48, 3, |_, _| rng.normal() * 0.8);
    let k = Kernel::Gaussian { bandwidth: 1.0 }.gram(&x);
    let mut t = Table::new(vec!["q", "s", "eps"]);
    for (q, s) in [(4usize, 1usize), (8, 1), (8, 2), (12, 2), (14, 4), (16, 6)] {
        let spec = FeatureSpec::new(gaussian(), Method::Gegenbauer { q, s }, 4096, 11);
        let z = spec.build(3).featurize(&x);
        let eps = spectral_epsilon(&k, &z.matmul_nt(&z), 0.1);
        t.row(vec![q.to_string(), s.to_string(), format!("{:.3}", eps)]);
    }
    t.print();

    // every registered method at the ablation's default budget — the
    // cross-method face of the same workload
    println!("\n== registry sweep: test mse per method (m = 512) ==");
    let mut t = Table::new(vec!["method", "F", "test mse"]);
    for (i, method) in Method::registry().into_iter().enumerate() {
        let spec = FeatureSpec::new(gaussian(), method.tuned(12, 2), 512, 20 + i as u64);
        let feat_dim = spec.feature_dim();
        let err = spec_mse(&spec, &xtr, &ytr, &xte, &yte);
        t.row(vec![spec.method.name().to_string(), feat_dim.to_string(), format!("{err:.4}")]);
    }
    t.print();
}
