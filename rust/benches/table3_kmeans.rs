//! Bench: regenerate Table 3 — kernel k-means over the six UCI-geometry
//! clustering datasets, six methods, m = 512 features.
//!
//! Run: cargo bench --bench table3_kmeans   (GZK_SCALE to resize)

use gzk::experiments::table3;

fn main() {
    let scale: f64 = std::env::var("GZK_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.05);
    let m: usize = std::env::var("GZK_M").ok().and_then(|s| s.parse().ok()).unwrap_or(512);
    let rows = table3::run_all(scale, m, 1);
    table3::print(&rows);
    println!("\n(scale {scale} of the paper's dataset sizes; m = {m})");
}
