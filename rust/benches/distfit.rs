//! Bench: the distributed fit over TCP vs the in-process one-round fit —
//! wall-clock at 1/2/4 workers on loopback, with the merged model
//! asserted **bit-identical** to the single-process fit at every fleet
//! size (the distributed tier's correctness contract, measured and
//! checked in the same run).
//!
//! Loopback workers share the machine, so this measures protocol +
//! scheduling overhead rather than true scale-out; the numbers still
//! track the serialization cost of shipping F x F Gram frames and the
//! leader's merge across PRs. Emits a machine-readable
//! `BENCH_distfit.json` (path overridable via `GZK_BENCH_JSON`; CI
//! uploads it as an artifact).
//!
//! Run: cargo bench --bench distfit

use gzk::bench::{fmt_secs, Table};
use gzk::coordinator::{fit_one_round_source, Backend};
use gzk::data::SyntheticSource;
use gzk::dist::{run_worker, DataSpec, DistLeader, LeaderConfig, WorkerOptions};
use gzk::features::{FeatureSpec, KernelSpec, Method};
use std::time::{Duration, Instant};

const N: usize = 20_000;
const M: usize = 256;
const CHUNK_ROWS: usize = 2048;
const LAMBDA: f64 = 1e-2;
const SEED: u64 = 1;

struct SweepRow {
    workers: usize,
    wall_secs: f64,
    featurize_secs_total: f64,
    bit_identical: bool,
}

fn main() {
    let fspec = FeatureSpec::new(
        KernelSpec::Gaussian { bandwidth: 1.0 },
        Method::Gegenbauer { q: 12, s: 2 },
        M,
        SEED,
    );
    let data = DataSpec { name: "elevation".to_string(), rows: N, seed: SEED };
    let src = SyntheticSource::by_name(&data.name, N, SEED).expect("elevation source");
    let spec = fspec.bind(src.dim());

    println!("== distributed fit over TCP vs in-process (n={N}, m={M}, chunk={CHUNK_ROWS}) ==");
    let t0 = Instant::now();
    let local = fit_one_round_source(&spec, &src, LAMBDA, 4, CHUNK_ROWS, Backend::Native)
        .expect("in-process fit");
    let local_secs = t0.elapsed().as_secs_f64();
    println!("in-process baseline: {} ({} shards)", fmt_secs(local_secs), local.n_shards);

    let mut sweep = Vec::new();
    for workers in [1usize, 2, 4] {
        let cfg = LeaderConfig {
            n_workers: workers,
            rows_per_shard: CHUNK_ROWS,
            register_timeout: Duration::from_secs(30),
            shard_timeout: Duration::from_secs(120),
        };
        let leader = DistLeader::bind("127.0.0.1:0", cfg).expect("bind leader");
        let addr = leader.local_addr().expect("leader addr").to_string();
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || run_worker(&addr, &WorkerOptions::default()))
            })
            .collect();
        let fit = leader.run(&spec, &data, LAMBDA).expect("distributed fit");
        for h in handles {
            h.join().expect("worker thread").expect("worker run");
        }
        let bit_identical = fit.model.weights.len() == local.model.weights.len()
            && fit
                .model
                .weights
                .iter()
                .zip(&local.model.weights)
                .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(bit_identical, "{workers}-worker fit drifted from the in-process fit");
        sweep.push(SweepRow {
            workers,
            wall_secs: fit.wall_secs,
            featurize_secs_total: fit.featurize_secs_total,
            bit_identical,
        });
    }

    let mut t = Table::new(vec!["workers", "wall", "featurize CPU", "vs in-process", "bit id"]);
    for r in &sweep {
        t.row(vec![
            format!("{}", r.workers),
            fmt_secs(r.wall_secs),
            fmt_secs(r.featurize_secs_total),
            format!("{:.2}x", local_secs / r.wall_secs),
            format!("{}", r.bit_identical),
        ]);
    }
    t.print();

    let path = std::env::var("GZK_BENCH_JSON").unwrap_or_else(|_| "BENCH_distfit.json".to_string());
    let rows: Vec<String> = sweep
        .iter()
        .map(|r| {
            format!(
                concat!(
                    r#"{{"workers":{},"wall_secs":{:.4},"featurize_secs_total":{:.4},"#,
                    r#""speedup_vs_local":{:.3},"bit_identical":{}}}"#
                ),
                r.workers,
                r.wall_secs,
                r.featurize_secs_total,
                local_secs / r.wall_secs,
                r.bit_identical
            )
        })
        .collect();
    let text = format!(
        concat!(
            r#"{{"format":1,"bench":"distfit","n":{},"m":{},"chunk_rows":{},"#,
            r#""local_secs":{:.4},"sweep":[{}]}}"#
        ),
        N,
        M,
        CHUNK_ROWS,
        local_secs,
        rows.join(",")
    );
    std::fs::write(&path, text).unwrap_or_else(|e| panic!("write {path}: {e}"));
    println!("\nwrote {path}");
}
