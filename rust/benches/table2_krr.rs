//! Bench: regenerate Table 2 — Gaussian-kernel KRR over the four regression
//! datasets, six methods, m = 1024 features.
//!
//! Run: cargo bench --bench table2_krr
//! Scale the dataset sizes with GZK_SCALE (fraction of the paper's n;
//! default 0.05 keeps the full 6-method sweep to a few minutes).

use gzk::experiments::table2;

fn main() {
    let scale: f64 = std::env::var("GZK_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.05);
    let m: usize = std::env::var("GZK_M").ok().and_then(|s| s.parse().ok()).unwrap_or(1024);
    let rows = table2::run_all(scale, m, 1);
    table2::print(&rows);
    println!("\n(scale {scale} of the paper's dataset sizes; m = {m})");
}
