//! Property tests for the `features::spec` registry: JSON round-tripping
//! (`encode -> decode -> build`) and the determinism invariant the
//! coordinator protocol relies on — two builds from the same spec produce
//! bit-identical feature matrices, even when one build happened on the far
//! side of a wire encoding. Extends the fixed-spec check in
//! `coordinator::protocol::tests::determinism_across_builders` to random
//! specs across every kernel family and oblivious method.

use gzk::coordinator::FeatureSpec as WireSpec;
use gzk::features::{FeatureSpec, Featurizer as _, KernelSpec, Method};
use gzk::linalg::Mat;
use gzk::rng::Rng;
use gzk::testutil::for_random_cases;

struct Case {
    spec: WireSpec,
    x: Mat,
}

fn gen_case(rng: &mut Rng) -> Case {
    let d = 2 + rng.below(4);
    let kernel = match rng.below(4) {
        0 => KernelSpec::Gaussian { bandwidth: 0.5 + rng.uniform() },
        1 => KernelSpec::Exponential { gamma: 0.4 + 0.5 * rng.uniform() },
        2 => KernelSpec::Polynomial { p: 2 + rng.below(3), c: rng.uniform() },
        _ => KernelSpec::Ntk { depth: 2 + rng.below(2) },
    };
    // non-gaussian kernels pair with the Gegenbauer method only; the
    // gaussian kernel exercises every oblivious registry method
    let method = if matches!(kernel, KernelSpec::Gaussian { .. }) {
        let oblivious: Vec<Method> =
            Method::registry().into_iter().filter(|m| m.is_oblivious()).collect();
        match oblivious[rng.below(oblivious.len())].clone() {
            Method::Gegenbauer { .. } => {
                Method::Gegenbauer { q: 3 + rng.below(8), s: 1 + rng.below(3) }
            }
            other => other,
        }
    } else {
        Method::Gegenbauer { q: 3 + rng.below(8), s: 1 + rng.below(3) }
    };
    let spec = FeatureSpec::new(kernel, method, 8 + rng.below(64), rng.next_u64()).bind(d);
    let x = Mat::from_fn(9, d, |_, _| rng.normal() * 0.6);
    Case { spec, x }
}

#[test]
fn prop_spec_json_roundtrip_is_lossless() {
    for_random_cases(0x5EC0, 24, gen_case, |c| {
        let text = c.spec.to_json();
        let decoded = WireSpec::from_json(&text).map_err(|e| format!("decode {text}: {e}"))?;
        if decoded != c.spec {
            return Err(format!("roundtrip changed the spec: {text}"));
        }
        // the unbound form round-trips too
        let unbound = FeatureSpec::from_json(&c.spec.spec.to_json())
            .map_err(|e| format!("unbound decode: {e}"))?;
        if unbound != c.spec.spec {
            return Err("unbound roundtrip changed the spec".into());
        }
        Ok(())
    });
}

#[test]
fn prop_decoded_spec_builds_bit_identical_features() {
    for_random_cases(0x5EC1, 16, gen_case, |c| {
        let z_local = c.spec.build().featurize(&c.x);
        if z_local.cols() != c.spec.feature_dim() {
            return Err(format!(
                "feature_dim {} != built dim {}",
                c.spec.feature_dim(),
                z_local.cols()
            ));
        }
        let decoded = WireSpec::from_json(&c.spec.to_json()).map_err(|e| e.to_string())?;
        let z_wire = decoded.build().featurize(&c.x);
        if z_local != z_wire {
            return Err(format!(
                "wire rebuild differs for {}",
                c.spec.spec.method.name()
            ));
        }
        // and a second local build agrees as well (pure determinism)
        if z_local != c.spec.build().featurize(&c.x) {
            return Err("two local builds differ".into());
        }
        Ok(())
    });
}
