//! Property tests on the coordinator invariants (DESIGN.md §7), using the
//! in-crate mini property runner (proptest is unavailable offline).
//!
//! Cases are drawn across *every* data-oblivious registry method — the
//! protocol invariants (worker/shard invariance, distributed == single
//! node, stream == batch, broadcast determinism) are method-agnostic.

use gzk::coordinator::{fit_one_round, Backend, FeatureSpec, KernelSpec, Method};
use gzk::coordinator::{PredictionService, StreamBatch, StreamingKrr};
use gzk::features::{FeatureSpec as Spec, Featurizer as _};
use gzk::krr::{FeatureRidge, RidgeStats};
use gzk::linalg::Mat;
use gzk::rng::Rng;
use gzk::testutil::for_random_cases;
use std::time::Duration;

struct Case {
    spec: FeatureSpec,
    x: Mat,
    y: Vec<f64>,
    lambda: f64,
    workers_a: usize,
    workers_b: usize,
    shard_a: usize,
    shard_b: usize,
}

fn gen_method(rng: &mut Rng) -> Method {
    // any oblivious registry method, with randomized gegenbauer knobs
    let oblivious: Vec<Method> =
        Method::registry().into_iter().filter(|m| m.is_oblivious()).collect();
    match oblivious[rng.below(oblivious.len())].clone() {
        Method::Gegenbauer { .. } => {
            Method::Gegenbauer { q: 3 + rng.below(8), s: 1 + rng.below(3) }
        }
        other => other,
    }
}

fn gen_case(rng: &mut Rng) -> Case {
    let d = 2 + rng.below(4);
    let n = 20 + rng.below(60);
    let spec = Spec::new(
        KernelSpec::Gaussian { bandwidth: 0.5 + rng.uniform() },
        gen_method(rng),
        8 * (1 + rng.below(6)),
        rng.next_u64(),
    )
    .bind(d);
    let x = Mat::from_fn(n, d, |_, _| rng.normal());
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    Case {
        spec,
        x,
        y,
        lambda: 10f64.powf(rng.uniform_in(-4.0, 0.0)),
        workers_a: 1 + rng.below(4),
        workers_b: 1 + rng.below(4),
        shard_a: 1 + rng.below(20),
        shard_b: 1 + rng.below(20),
    }
}

#[test]
fn prop_fit_invariant_to_workers_and_sharding() {
    for_random_cases(0xC0FFEE, 12, gen_case, |c| {
        let fa = fit_one_round(
            &c.spec, &c.x, &c.y, c.lambda, c.workers_a, c.shard_a, Backend::Native,
        );
        let fb = fit_one_round(
            &c.spec, &c.x, &c.y, c.lambda, c.workers_b, c.shard_b, Backend::Native,
        );
        for (i, (a, b)) in fa.model.weights.iter().zip(&fb.model.weights).enumerate() {
            if (a - b).abs() > 1e-8 * (1.0 + a.abs()) {
                return Err(format!("weight[{i}] differs: {a} vs {b}"));
            }
        }
        if fa.stats.n != c.x.rows() {
            return Err(format!("row count {} != {}", fa.stats.n, c.x.rows()));
        }
        Ok(())
    });
}

#[test]
fn prop_distributed_equals_single_node() {
    for_random_cases(0xBEEF, 10, gen_case, |c| {
        let fit = fit_one_round(&c.spec, &c.x, &c.y, c.lambda, c.workers_a, c.shard_a, Backend::Native);
        let z = c.spec.build().featurize(&c.x);
        let reference = FeatureRidge::fit(&z, &c.y, c.lambda);
        for (a, b) in fit.model.weights.iter().zip(&reference.weights) {
            if (a - b).abs() > 1e-8 * (1.0 + a.abs()) {
                return Err(format!("distributed {a} vs single {b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_streaming_equals_batch() {
    for_random_cases(0xFEED, 8, gen_case, |c| {
        let stream = StreamingKrr::start(c.spec.clone(), 2);
        let mut lo = 0;
        let mut step = 3;
        while lo < c.x.rows() {
            let hi = (lo + step).min(c.x.rows());
            stream
                .handle()
                .push(StreamBatch { x: c.x.row_block(lo, hi), y: c.y[lo..hi].to_vec() })
                .map_err(|e| e.to_string())?;
            lo = hi;
            step = step % 7 + 2; // irregular batch sizes
        }
        let (model, stats) = stream.finalize(c.lambda);
        if stats.n != c.x.rows() {
            return Err("row loss in stream".into());
        }
        let z = c.spec.build().featurize(&c.x);
        let reference = FeatureRidge::fit(&z, &c.y, c.lambda);
        for (a, b) in model.weights.iter().zip(&reference.weights) {
            if (a - b).abs() > 1e-8 * (1.0 + a.abs()) {
                return Err(format!("stream {a} vs batch {b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_stats_merge_commutative_associative() {
    for_random_cases(0xABBA, 15, gen_case, |c| {
        let z = c.spec.build().featurize(&c.x);
        let f = z.cols();
        let third = c.x.rows() / 3;
        if third == 0 {
            return Ok(());
        }
        let mk = |lo: usize, hi: usize| {
            let mut s = RidgeStats::new(f);
            s.absorb(&z.row_block(lo, hi), &c.y[lo..hi]);
            s
        };
        let (s1, s2, s3) = (mk(0, third), mk(third, 2 * third), mk(2 * third, c.x.rows()));
        // (s1 + s2) + s3
        let mut a = RidgeStats::new(f);
        a.merge(&s1);
        a.merge(&s2);
        a.merge(&s3);
        // s3 + (s2 + s1)
        let mut b = RidgeStats::new(f);
        b.merge(&s3);
        b.merge(&s2);
        b.merge(&s1);
        if a.g.max_abs_diff(&b.g) > 1e-9 {
            return Err("merge not order-invariant".into());
        }
        if a.n != b.n || (a.yy - b.yy).abs() > 1e-9 {
            return Err("counters differ".into());
        }
        Ok(())
    });
}

#[test]
fn prop_stats_shard_split_and_order_invariant() {
    // the invariant the distributed fit relies on: absorbing all rows at
    // once equals splitting them into arbitrary contiguous shards,
    // absorbing each, and merging the shards in ANY order — for G, b, n
    // and yy alike
    for_random_cases(0x51AB, 12, gen_case, |c| {
        let z = c.spec.build().featurize(&c.x);
        let n = z.rows();
        let f = z.cols();
        // reference: one absorb over the whole dataset
        let mut whole = RidgeStats::new(f);
        whole.absorb(&z, &c.y);
        // random cut points -> shards of irregular sizes (empty-free)
        let mut rng = Rng::new(c.spec.spec.seed ^ 0x5EED);
        let mut cuts = vec![0, n];
        for _ in 0..(1 + rng.below(5)) {
            cuts.push(rng.below(n + 1));
        }
        cuts.sort_unstable();
        cuts.dedup();
        let mut shards: Vec<RidgeStats> = cuts
            .windows(2)
            .map(|w| {
                let mut s = RidgeStats::new(f);
                s.absorb(&z.row_block(w[0], w[1]), &c.y[w[0]..w[1]]);
                s
            })
            .collect();
        // merge in a random order
        rng.shuffle(&mut shards);
        let mut merged = RidgeStats::new(f);
        for s in &shards {
            merged.merge(s);
        }
        if merged.n != whole.n {
            return Err(format!("row count {} != {}", merged.n, whole.n));
        }
        if merged.g.max_abs_diff(&whole.g) > 1e-9 {
            return Err(format!(
                "G differs by {} across {} shards",
                merged.g.max_abs_diff(&whole.g),
                shards.len()
            ));
        }
        for (i, (a, b)) in merged.b.iter().zip(&whole.b).enumerate() {
            if (a - b).abs() > 1e-9 * (1.0 + a.abs()) {
                return Err(format!("b[{i}] differs: {a} vs {b}"));
            }
        }
        if (merged.yy - whole.yy).abs() > 1e-9 * (1.0 + whole.yy.abs()) {
            return Err(format!("yy differs: {} vs {}", merged.yy, whole.yy));
        }
        // and the solved models agree
        let ma = merged.solve(c.lambda);
        let mb = whole.solve(c.lambda);
        for (a, b) in ma.weights.iter().zip(&mb.weights) {
            if (a - b).abs() > 1e-8 * (1.0 + a.abs()) {
                return Err(format!("solved weights differ: {a} vs {b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_service_answers_every_request_exactly_once() {
    for_random_cases(0xD00D, 6, gen_case, |c| {
        let z = c.spec.build().featurize(&c.x);
        let model = FeatureRidge::fit(&z, &c.y, c.lambda);
        let expect = model.predict(&z);
        let svc = PredictionService::start(
            c.spec.clone(),
            model,
            1 + (c.shard_a % 8),
            Duration::from_micros(300),
        )
        .map_err(|e| format!("start service: {e}"))?;
        // concurrent clients with interleaved indices
        let mut joins = Vec::new();
        for t in 0..3usize {
            let client = svc.client();
            let idx: Vec<usize> = (0..c.x.rows()).skip(t).step_by(3).collect();
            let rows = Mat::from_fn(idx.len(), c.x.cols(), |r, j| c.x[(idx[r], j)]);
            let exp: Vec<f64> = idx.iter().map(|&i| expect[i]).collect();
            joins.push(std::thread::spawn(move || {
                for (r, e) in exp.iter().enumerate() {
                    let p = client.predict(rows.row(r)).expect("served");
                    assert!((p - e).abs() < 1e-9, "prediction mismatch");
                }
                rows.rows()
            }));
        }
        let mut answered = 0;
        for j in joins {
            answered += j.join().map_err(|_| "client thread panicked".to_string())?;
        }
        if answered != c.x.rows() {
            return Err(format!("answered {answered} of {}", c.x.rows()));
        }
        let m = svc.metrics();
        if m.requests != c.x.rows() {
            return Err(format!("service counted {} requests", m.requests));
        }
        Ok(())
    });
}

#[test]
fn prop_feature_map_oblivious_reconstruction() {
    // the broadcast property: two independent builders of the same spec —
    // one from the value, one from its wire encoding — featurize
    // identically, across every random (method, kernel, m, seed) spec
    for_random_cases(0x0B11, 20, gen_case, |c| {
        let f1 = c.spec.build();
        let f2 = FeatureSpec::from_json(&c.spec.to_json())
            .map_err(|e| format!("wire decode: {e}"))?
            .build();
        let z1 = f1.featurize(&c.x);
        let z2 = f2.featurize(&c.x);
        if z1 != z2 {
            return Err("same spec produced different features".into());
        }
        Ok(())
    });
}
