//! End-to-end tests of the L4 network serving subsystem, over real
//! sockets: fit → persist → `Server` → TCP clients receive predictions
//! **bit-identical** to a direct `Model::predict`; multi-model routing;
//! manifest-poll hot-reload (new artifact served without restart, changed
//! artifact swapped in); pipelined requests answered in order;
//! backpressure replies under a tiny admission bound; the negotiated
//! binary frame mode (bit-identical to JSON, hostile frames close the
//! connection but never the server, the dist proxy relays frames
//! verbatim); a 1000-connection smoke on the event-loop multiplexer with
//! a bounded thread count; and the in-process loadgen harness (trials at
//! two client counts, JSON-vs-binary cross-check, `BENCH_serve.json`).

use gzk::dist::{Proxy, ProxyConfig};
use gzk::features::{FeatureSpec, KernelSpec, Method};
use gzk::linalg::Mat;
use gzk::model::{KmeansModel, Model, ModelStore, RidgeModel};
use gzk::rng::Rng;
use gzk::server::frame::{self, FrameReply};
use gzk::server::{wire, ClientConn, LoadgenConfig, Server, ServerConfig, WireMode};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("gzk-server-e2e-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn ridge(d: usize, seed: u64) -> RidgeModel {
    let spec = FeatureSpec::new(
        KernelSpec::Gaussian { bandwidth: 1.0 },
        Method::Gegenbauer { q: 5, s: 1 },
        16,
        seed,
    )
    .bind(d);
    let mut rng = Rng::new(seed ^ 0xABCD);
    let x = Mat::from_fn(50, d, |_, _| rng.normal() * 0.5);
    let y: Vec<f64> = (0..50).map(|i| x[(i, 0)] + 0.3 * x[(i, d - 1)]).collect();
    RidgeModel::fit(spec, &x, &y, 1e-3).unwrap()
}

fn predict_bits(model: &dyn Model, x: &[f64]) -> Vec<u64> {
    let out = model.predict(&Mat::from_vec(1, x.len(), x.to_vec()));
    out.row(0).iter().map(|v| v.to_bits()).collect()
}

fn reply_bits(reply: &wire::Reply) -> Vec<u64> {
    reply.y().unwrap().iter().map(|v| v.to_bits()).collect()
}

fn test_config() -> ServerConfig {
    ServerConfig { poll: Duration::from_millis(25), ..ServerConfig::default() }
}

#[test]
fn serves_models_bit_identically_with_full_protocol_coverage() {
    let dir = fresh_dir("protocol");
    let store = ModelStore::open(&dir).unwrap();
    let ridge_model = ridge(2, 11);
    store.save("ridge", &ridge_model).unwrap();
    // a second model of a different kind: routing is by name
    let kspec = FeatureSpec::new(
        KernelSpec::Gaussian { bandwidth: 1.0 },
        Method::Gegenbauer { q: 4, s: 1 },
        12,
        21,
    )
    .bind(2);
    let mut rng = Rng::new(5);
    let xk = Mat::from_fn(30, 2, |i, _| {
        let center = if i % 2 == 0 { 1.0 } else { -1.0 };
        center + 0.1 * rng.normal()
    });
    let kmeans_model = KmeansModel::fit(kspec, &xk, 2, 20).unwrap();
    store.save("clusters", &kmeans_model).unwrap();

    let server = Server::start(&dir, "127.0.0.1:0", test_config()).unwrap();
    assert_eq!(server.model_names(), vec!["clusters".to_string(), "ridge".to_string()]);
    let addr = server.local_addr().to_string();
    let mut conn = ClientConn::connect(&addr).unwrap();

    // ping + models
    let pong = conn.roundtrip(&wire::cmd_request("ping")).unwrap();
    assert!(pong.ok, "{pong:?}");
    let models = conn.roundtrip(&wire::cmd_request("models")).unwrap();
    assert!(models.ok);
    assert!(models.raw.contains(r#""name":"ridge""#), "{}", models.raw);
    assert!(models.raw.contains(r#""name":"clusters""#), "{}", models.raw);

    // predictions on both routes are bit-identical to the local models
    let probes = [[0.25, -0.7], [1.0, 0.9], [-1.1, 0.05]];
    for x in &probes {
        let r = conn.roundtrip(&wire::predict_request(Some("ridge"), x)).unwrap();
        assert_eq!(reply_bits(&r), predict_bits(&ridge_model, x), "ridge {x:?}");
        let r = conn.roundtrip(&wire::predict_request(Some("clusters"), x)).unwrap();
        assert_eq!(reply_bits(&r), predict_bits(&kmeans_model, x), "clusters {x:?}");
    }

    // error paths keep the connection alive and name the problem
    let r = conn.roundtrip(&wire::predict_request(None, &probes[0])).unwrap();
    assert!(!r.ok && r.error.as_deref().unwrap().contains("multiple models"), "{r:?}");
    let r = conn.roundtrip(&wire::predict_request(Some("nope"), &probes[0])).unwrap();
    assert!(!r.ok && r.error.as_deref().unwrap().contains("no model"), "{r:?}");
    let r = conn.roundtrip(&wire::predict_request(Some("ridge"), &[1.0, 2.0, 3.0])).unwrap();
    assert!(!r.ok && r.error.as_deref().unwrap().contains("expects d = 2"), "{r:?}");
    let r = conn.roundtrip("this is not json").unwrap();
    assert!(!r.ok && r.error.as_deref().unwrap().contains("malformed"), "{r:?}");

    // stats: the ridge route served 3 + 0 failed; fields are present
    let stats = conn.roundtrip(&wire::cmd_request("stats")).unwrap();
    assert!(stats.ok);
    for field in
        ["\"requests\":", "\"p50_us\":", "\"p99_us\":", "\"queue_depth\":", "\"rejects\":"]
    {
        assert!(stats.raw.contains(field), "missing {field}: {}", stats.raw);
    }

    // shutdown is acked, then the server winds down
    let bye = conn.roundtrip(&wire::cmd_request("shutdown")).unwrap();
    assert!(bye.ok && bye.raw.contains("stopping"), "{bye:?}");
    let final_stats = server.wait();
    assert!(final_stats.contains("\"requests\":"), "{final_stats}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn hostile_request_lines_degrade_to_error_replies_not_a_dead_server() {
    let dir = fresh_dir("hostile");
    let store = ModelStore::open(&dir).unwrap();
    let model = ridge(2, 33);
    store.save("ridge", &model).unwrap();
    let server = Server::start(&dir, "127.0.0.1:0", test_config()).unwrap();
    let addr = server.local_addr().to_string();

    // deeply nested JSON: used to recurse to a reader-thread stack
    // overflow (a process abort); must now be a malformed-request reply
    // on a connection that stays open
    let mut conn = ClientConn::connect(&addr).unwrap();
    let nested = "[".repeat(100_000);
    let r = conn.roundtrip(&nested).unwrap();
    assert!(!r.ok && r.error.as_deref().unwrap().contains("malformed"), "{r:?}");
    // a truncated \u escape: used to slice out of bounds (reader panic)
    let r = conn.roundtrip(r#"{"cmd":"ping","pad":"\u1"#).unwrap();
    assert!(!r.ok && r.error.as_deref().unwrap().contains("malformed"), "{r:?}");
    // the same connection still serves
    let pong = conn.roundtrip(&wire::cmd_request("ping")).unwrap();
    assert!(pong.ok, "{pong:?}");

    // a newline-free flood past the line cap: one error reply, then the
    // server closes the connection (nothing to resynchronize on)
    let stream = TcpStream::connect(server.local_addr()).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let chunk = vec![b'x'; 1 << 16];
    let mut sent = 0usize;
    while sent <= gzk::server::listener::MAX_LINE_BYTES + (1 << 16) {
        if writer.write_all(&chunk).is_err() {
            break; // server already replied and closed; that is the point
        }
        sent += chunk.len();
    }
    let _ = writer.flush();
    // the server replies once and closes; our surplus unread bytes may
    // turn that close into an RST that races the reply, so accept either
    // a well-formed "exceeds" error or a reset — but never a prediction
    let mut line = String::new();
    match reader.read_line(&mut line) {
        Ok(n) if n > 0 => {
            let reply = wire::parse_reply(line.trim_end()).unwrap();
            assert!(
                !reply.ok && reply.error.as_deref().unwrap().contains("exceeds"),
                "{reply:?}"
            );
            // ... and the connection is closed afterwards
            line.clear();
            let _ = reader.read_line(&mut line);
            assert!(line.is_empty(), "expected EOF, got {line:?}");
        }
        _ => {} // connection reset before the reply could be read
    }

    // the server is still fully alive for new connections
    let mut conn2 = ClientConn::connect(&addr).unwrap();
    let x = [0.3, -0.4];
    let r = conn2.roundtrip(&wire::predict_request(Some("ridge"), &x)).unwrap();
    assert_eq!(reply_bits(&r), predict_bits(&model, &x));

    server.shutdown();
    server.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn hot_reload_picks_up_new_and_changed_artifacts_without_restart() {
    let dir = fresh_dir("reload");
    let store = ModelStore::open(&dir).unwrap();
    let v1 = ridge(2, 100);
    store.save("a", &v1).unwrap();
    let server = Server::start(&dir, "127.0.0.1:0", test_config()).unwrap();
    let addr = server.local_addr().to_string();
    let mut conn = ClientConn::connect(&addr).unwrap();
    let x = [0.4, -0.2];
    let r = conn.roundtrip(&wire::predict_request(Some("a"), &x)).unwrap();
    assert_eq!(reply_bits(&r), predict_bits(&v1, &x));

    // 1) a NEW artifact persisted into the live store starts serving
    let b = ridge(2, 200);
    store.save("b", &b).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let r = conn.roundtrip(&wire::predict_request(Some("b"), &x)).unwrap();
        if r.ok {
            assert_eq!(reply_bits(&r), predict_bits(&b, &x), "hot-added model must match");
            break;
        }
        assert!(Instant::now() < deadline, "poller never served the new artifact: {r:?}");
        std::thread::sleep(Duration::from_millis(10));
    }

    // 2) REPLACING an artifact swaps the served model (the fingerprint —
    // length + mtime — changes; sleep past coarse mtime granularity)
    std::thread::sleep(Duration::from_millis(30));
    let v2 = ridge(2, 300);
    assert_ne!(
        predict_bits(&v1, &x),
        predict_bits(&v2, &x),
        "test needs distinguishable models"
    );
    store.save("a", &v2).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let r = conn.roundtrip(&wire::predict_request(Some("a"), &x)).unwrap();
        let bits = reply_bits(&r);
        if bits == predict_bits(&v2, &x) {
            break; // swapped in
        }
        assert_eq!(bits, predict_bits(&v1, &x), "reply matches neither version");
        assert!(Instant::now() < deadline, "poller never swapped the changed artifact");
        std::thread::sleep(Duration::from_millis(10));
    }

    server.shutdown();
    server.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pipelined_requests_are_answered_in_order() {
    let dir = fresh_dir("pipeline");
    let store = ModelStore::open(&dir).unwrap();
    let model = ridge(2, 7);
    store.save("ridge", &model).unwrap();
    let server = Server::start(&dir, "127.0.0.1:0", test_config()).unwrap();
    let addr = server.local_addr();

    // write 30 requests without reading a single reply, then read all 30:
    // replies must come back in request order (checked by value — every
    // row has a distinct prediction)
    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let rows: Vec<[f64; 2]> = (0..30).map(|i| [0.1 * i as f64, 1.0 - 0.05 * i as f64]).collect();
    for x in &rows {
        writeln!(writer, "{}", wire::predict_request(Some("ridge"), x)).unwrap();
    }
    writer.flush().unwrap();
    for (i, x) in rows.iter().enumerate() {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "reply {i} missing");
        let reply = wire::parse_reply(line.trim_end()).unwrap();
        assert!(reply.ok, "reply {i}: {reply:?}");
        assert_eq!(reply_bits(&reply), predict_bits(&model, x), "reply {i} out of order");
    }

    // concurrent connections stay isolated: 4 clients, disjoint rows
    std::thread::scope(|scope| {
        for t in 0..4usize {
            let addr = addr.to_string();
            let model = &model;
            scope.spawn(move || {
                let mut conn = ClientConn::connect(&addr).unwrap();
                for r in 0..25usize {
                    let x = [t as f64 * 0.3 + r as f64 * 0.01, -(r as f64) * 0.02];
                    let reply =
                        conn.roundtrip(&wire::predict_request(Some("ridge"), &x)).unwrap();
                    assert_eq!(reply_bits(&reply), predict_bits(model, &x), "client {t} row {r}");
                }
            });
        }
    });

    server.shutdown();
    server.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tiny_admission_bound_sheds_load_with_retriable_replies() {
    let dir = fresh_dir("backpressure");
    let store = ModelStore::open(&dir).unwrap();
    let model = ridge(2, 9);
    store.save("ridge", &model).unwrap();
    let cfg = ServerConfig {
        max_queue: 1,
        max_batch: 1,
        poll: Duration::from_millis(25),
        ..ServerConfig::default()
    };
    let server = Server::start(&dir, "127.0.0.1:0", cfg).unwrap();
    let addr = server.local_addr();

    // flood: 50 pipelined requests against a 1-deep queue. Every request
    // gets exactly one reply, each is either a correct prediction or a
    // retriable overload — and the reply order still matches the
    // request order for the admitted ones.
    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let rows: Vec<[f64; 2]> = (0..50).map(|i| [0.07 * i as f64, 0.5 - 0.01 * i as f64]).collect();
    for x in &rows {
        writeln!(writer, "{}", wire::predict_request(Some("ridge"), x)).unwrap();
    }
    writer.flush().unwrap();
    let (mut oks, mut overloads) = (0usize, 0usize);
    for (i, x) in rows.iter().enumerate() {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "reply {i} missing");
        let reply = wire::parse_reply(line.trim_end()).unwrap();
        if reply.ok {
            assert_eq!(reply_bits(&reply), predict_bits(&model, x), "reply {i}");
            oks += 1;
        } else {
            assert!(reply.retry, "only overloads may fail here: {reply:?}");
            overloads += 1;
        }
    }
    assert_eq!(oks + overloads, 50);
    assert!(oks >= 1, "at least the first request must be admitted");
    // the server's stats agree with what the client observed
    let mut conn = ClientConn::connect(&addr.to_string()).unwrap();
    let stats = conn.roundtrip(&wire::cmd_request("stats")).unwrap();
    assert!(stats.raw.contains(&format!(r#""rejects":{overloads}"#)), "{}", stats.raw);
    assert!(stats.raw.contains(&format!(r#""requests":{oks}"#)), "{}", stats.raw);

    server.shutdown();
    server.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn loadgen_measures_verifies_and_shuts_down_the_server() {
    let dir = fresh_dir("loadgen");
    let store = ModelStore::open(&dir).unwrap();
    // elevation-compatible input dimension (loadgen's default dataset)
    let model = ridge(3, 55);
    store.save("ridge", &model).unwrap();
    let server = Server::start(&dir, "127.0.0.1:0", test_config()).unwrap();
    let addr = server.local_addr().to_string();

    let cfg = LoadgenConfig {
        addr,
        clients: vec![1, 3],
        requests_per_client: 25,
        dataset: None, // defaults to elevation (d = 3)
        model: None,   // the single served model
        store: Some(dir.clone()),
        seed: 4,
        send_shutdown: true,
        ..LoadgenConfig::default()
    };
    let report = gzk::server::loadgen::run(&cfg).expect("loadgen run");
    assert_eq!(report.model, "ridge");
    assert_eq!(report.dataset, "elevation");
    assert!(report.verified);
    assert_eq!(report.mismatches(), 0, "server replies diverged from the local model");
    assert_eq!(report.trials.len(), 2);
    for (trial, want_clients) in report.trials.iter().zip([1usize, 3]) {
        assert_eq!(trial.clients, want_clients);
        assert_eq!(trial.requests, want_clients * 25);
        assert!(trial.wall_secs > 0.0 && trial.throughput_rps > 0.0);
        assert!(trial.p50_us > 0.0 && trial.p50_us <= trial.p99_us);
    }
    assert_eq!(report.server_stats.len(), 2);
    assert!(report.server_stats[1].contains("\"requests\":"), "{}", report.server_stats[1]);

    // the JSON artifact round-trips through the in-crate parser and
    // reports both client counts
    let json_path = dir.join("BENCH_serve.json");
    report.write_json(&json_path).unwrap();
    let text = std::fs::read_to_string(&json_path).unwrap();
    let parsed = gzk::runtime::Json::parse(&text).expect("valid JSON");
    let trials = parsed.get("trials").and_then(|t| t.as_arr()).expect("trials[]");
    assert_eq!(trials.len(), 2);
    assert!(trials[0].get("throughput_rps").and_then(|v| v.as_f64()).unwrap() > 0.0);
    assert!(trials[1].get("p99_us").and_then(|v| v.as_f64()).unwrap() > 0.0);

    // loadgen's --shutdown already stopped the server
    server.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn binary_frames_round_trip_bit_identically_with_json() {
    let dir = fresh_dir("binary");
    let store = ModelStore::open(&dir).unwrap();
    let model = ridge(2, 77);
    store.save("ridge", &model).unwrap();
    let server = Server::start(&dir, "127.0.0.1:0", test_config()).unwrap();
    let addr = server.local_addr().to_string();

    let mut bin = ClientConn::connect(&addr).unwrap();
    bin.upgrade_binary().unwrap();
    let mut json = ClientConn::connect(&addr).unwrap();

    // ping → pong over frames
    let pong = bin.roundtrip_frame(&frame::frame(&frame::ping_payload())).unwrap();
    assert!(matches!(frame::parse_reply(frame::payload(&pong)).unwrap(), FrameReply::Pong));

    // awkward floats included: subnormal, negative zero
    let probes = [[0.25, -0.7], [1.0, 0.9], [-1.1, 0.05], [5e-324, -0.0]];
    for x in &probes {
        let req = frame::frame(&frame::predict_payload(Some("ridge"), x));
        let reply = bin.roundtrip_frame(&req).unwrap();
        let y = match frame::parse_reply(frame::payload(&reply)).unwrap() {
            FrameReply::Ok { y } => y,
            other => panic!("expected an ok frame, got {other:?}"),
        };
        let bits: Vec<u64> = y.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits, predict_bits(&model, x), "binary {x:?}");
        // ... and identical to the same request over a JSON connection
        let jr = json.roundtrip(&wire::predict_request(Some("ridge"), x)).unwrap();
        assert_eq!(bits, reply_bits(&jr), "binary vs JSON {x:?}");
    }

    // request errors stay frames and keep the connection serving
    let req = frame::frame(&frame::predict_payload(Some("nope"), &probes[0]));
    let reply = bin.roundtrip_frame(&req).unwrap();
    match frame::parse_reply(frame::payload(&reply)).unwrap() {
        FrameReply::Err { msg, retry } => {
            assert!(msg.contains("no model") && !retry, "{msg}");
        }
        other => panic!("expected an error frame, got {other:?}"),
    }
    let pong = bin.roundtrip_frame(&frame::frame(&frame::ping_payload())).unwrap();
    assert_eq!(frame::reply_status(&pong), Some(frame::ST_PONG));

    server.shutdown();
    server.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn hostile_frames_close_the_connection_but_never_the_server() {
    let dir = fresh_dir("hostile-frames");
    let store = ModelStore::open(&dir).unwrap();
    let model = ridge(2, 88);
    store.save("ridge", &model).unwrap();
    let server = Server::start(&dir, "127.0.0.1:0", test_config()).unwrap();
    let addr = server.local_addr().to_string();

    // garbage magic: one error frame naming the problem, then close
    let mut c = ClientConn::connect(&addr).unwrap();
    c.upgrade_binary().unwrap();
    c.send_frame(b"XXXXXXXXXXXXXXXX").unwrap();
    let reply = c.read_frame().unwrap();
    match frame::parse_reply(frame::payload(&reply)).unwrap() {
        FrameReply::Err { msg, .. } => assert!(msg.contains("magic"), "{msg}"),
        other => panic!("expected an error frame, got {other:?}"),
    }
    assert!(c.read_frame().is_err(), "connection must close after bad magic");

    // an oversized length prefix is rejected from the header alone — the
    // payload is never awaited, let alone allocated
    let mut c = ClientConn::connect(&addr).unwrap();
    c.upgrade_binary().unwrap();
    let mut evil = Vec::from(frame::MAGIC);
    evil.extend_from_slice(&0x7FFF_FFFFu32.to_le_bytes());
    c.send_frame(&evil).unwrap();
    let reply = c.read_frame().unwrap();
    match frame::parse_reply(frame::payload(&reply)).unwrap() {
        FrameReply::Err { msg, .. } => assert!(msg.contains("exceeds"), "{msg}"),
        other => panic!("expected an error frame, got {other:?}"),
    }
    assert!(c.read_frame().is_err(), "connection must close after an oversized prefix");

    // a truncated frame followed by a disconnect is server-side cleanup
    let mut c = ClientConn::connect(&addr).unwrap();
    c.upgrade_binary().unwrap();
    let mut partial = Vec::from(frame::MAGIC);
    partial.extend_from_slice(&100u32.to_le_bytes());
    partial.extend_from_slice(&[0u8; 10]); // 10 of the promised 100 bytes
    c.send_frame(&partial).unwrap();
    drop(c);

    // the server is fully alive afterwards, over both protocols
    let mut c = ClientConn::connect(&addr).unwrap();
    let x = [0.3, -0.4];
    let r = c.roundtrip(&wire::predict_request(Some("ridge"), &x)).unwrap();
    assert_eq!(reply_bits(&r), predict_bits(&model, &x));
    c.upgrade_binary().unwrap();
    let reply =
        c.roundtrip_frame(&frame::frame(&frame::predict_payload(Some("ridge"), &x))).unwrap();
    match frame::parse_reply(frame::payload(&reply)).unwrap() {
        FrameReply::Ok { y } => {
            let bits: Vec<u64> = y.iter().map(|v| v.to_bits()).collect();
            assert_eq!(bits, predict_bits(&model, &x));
        }
        other => panic!("expected an ok frame, got {other:?}"),
    }

    server.shutdown();
    server.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[cfg(target_os = "linux")]
fn proc_thread_count() -> usize {
    let status = std::fs::read_to_string("/proc/self/status").unwrap_or_default();
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(0)
}

#[test]
fn a_thousand_concurrent_connections_multiplex_on_a_bounded_thread_count() {
    let dir = fresh_dir("c1k");
    let store = ModelStore::open(&dir).unwrap();
    let model = ridge(2, 99);
    store.save("ridge", &model).unwrap();

    // fd budget in THIS process: 2 per client `ClientConn` (stream +
    // BufReader clone) plus 1 server-side per connection; scale the
    // count down if the hard limit will not cover 1000
    let limit = gzk::server::sys::raise_nofile_limit(8192);
    let n_conns: usize = if limit >= 4096 { 1000 } else { 200 };

    #[cfg(target_os = "linux")]
    let threads_before = proc_thread_count();

    let cfg = ServerConfig {
        max_conns: n_conns + 200,
        poll: Duration::from_millis(100),
        ..ServerConfig::default()
    };
    let server = Server::start(&dir, "127.0.0.1:0", cfg).unwrap();
    let addr = server.local_addr().to_string();

    // open every connection up front and keep all of them alive
    let mut conns = Vec::with_capacity(n_conns);
    for i in 0..n_conns {
        match ClientConn::connect(&addr) {
            Ok(c) => conns.push(c),
            Err(e) => panic!("connect {i}/{n_conns}: {e}"),
        }
    }

    // thread count is O(event loops + pool), not O(connections): the old
    // two-threads-per-connection design would show up as 2000+ here.
    // (Other tests in this binary run concurrently and spawn their own
    // servers, so the bound is loose — the claim it checks is the order
    // of growth, not an exact census.)
    #[cfg(target_os = "linux")]
    {
        let delta = proc_thread_count().saturating_sub(threads_before);
        assert!(
            delta < 200,
            "serving {n_conns} connections grew the process by {delta} threads"
        );
    }

    // every 5th connection predicts on its own distinct inputs: a reply
    // lost, duplicated, or cross-wired between connections cannot pass
    for (i, conn) in conns.iter_mut().enumerate().filter(|(i, _)| i % 5 == 0) {
        let x = [0.001 * i as f64, 1.0 - 0.0005 * i as f64];
        let r = conn.roundtrip(&wire::predict_request(Some("ridge"), &x)).unwrap();
        assert_eq!(reply_bits(&r), predict_bits(&model, &x), "conn {i}");
    }
    // ... and every connection is still alive and answers a ping
    for (i, conn) in conns.iter_mut().enumerate() {
        let pong = conn.roundtrip(&wire::cmd_request("ping")).unwrap();
        assert!(pong.ok, "conn {i} lost its ping: {pong:?}");
    }

    drop(conns);
    server.shutdown();
    server.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn proxy_relays_binary_frames_verbatim_across_replicas() {
    let dir = fresh_dir("proxy-binary");
    let store = ModelStore::open(&dir).unwrap();
    let model = ridge(2, 123);
    store.save("ridge", &model).unwrap();
    let s1 = Server::start(&dir, "127.0.0.1:0", test_config()).unwrap();
    let s2 = Server::start(&dir, "127.0.0.1:0", test_config()).unwrap();
    let replicas = vec![s1.local_addr().to_string(), s2.local_addr().to_string()];
    let proxy = Proxy::start("127.0.0.1:0", replicas, ProxyConfig::default()).unwrap();
    let addr = proxy.local_addr().to_string();

    let mut conn = ClientConn::connect(&addr).unwrap();
    conn.upgrade_binary().unwrap();
    // enough requests that round-robin touches both replicas; replies
    // stay bit-identical to the local model through the relay
    let probes = [[0.25, -0.7], [1.0, 0.9], [-1.1, 0.05], [0.0, 1.0]];
    for x in probes.iter().cycle().take(10) {
        let req = frame::frame(&frame::predict_payload(Some("ridge"), x));
        let reply = conn.roundtrip_frame(&req).unwrap();
        let y = match frame::parse_reply(frame::payload(&reply)).unwrap() {
            FrameReply::Ok { y } => y,
            other => panic!("expected an ok frame through the proxy, got {other:?}"),
        };
        let bits: Vec<u64> = y.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits, predict_bits(&model, x), "{x:?}");
    }
    let pong = conn.roundtrip_frame(&frame::frame(&frame::ping_payload())).unwrap();
    assert_eq!(frame::reply_status(&pong), Some(frame::ST_PONG));

    proxy.shutdown();
    let _ = proxy.wait();
    s1.shutdown();
    s2.shutdown();
    let _ = s1.wait();
    let _ = s2.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn loadgen_wire_compare_proves_json_and_binary_bit_identical() {
    let dir = fresh_dir("wire-compare");
    let store = ModelStore::open(&dir).unwrap();
    // elevation-compatible input dimension (loadgen's default dataset)
    let model = ridge(3, 44);
    store.save("ridge", &model).unwrap();
    let server = Server::start(&dir, "127.0.0.1:0", test_config()).unwrap();

    let cfg = LoadgenConfig {
        addr: server.local_addr().to_string(),
        clients: vec![2],
        requests_per_client: 15,
        store: Some(dir.clone()),
        seed: 9,
        send_shutdown: true,
        wire: WireMode::Compare,
        ..LoadgenConfig::default()
    };
    let report = gzk::server::loadgen::run(&cfg).expect("loadgen compare run");
    assert_eq!(report.trials.len(), 2, "one JSON + one binary trial");
    assert_eq!(report.trials[0].wire, "json");
    assert_eq!(report.trials[1].wire, "binary");
    assert_eq!(report.mismatches(), 0);
    assert_eq!(report.trials[1].cross_mismatches, 0, "JSON and binary replies diverged");
    // the server ran in-process, so its admission registry counter is in
    // OUR registry and the cross-check must have engaged
    assert!(report.admission_rejected_total.is_some(), "registry cross-check must engage");

    // format-4 artifact: the wire + cross-check fields round-trip the
    // in-crate parser
    let json_path = dir.join("BENCH_serve.json");
    report.write_json(&json_path).unwrap();
    let text = std::fs::read_to_string(&json_path).unwrap();
    let parsed = gzk::runtime::Json::parse(&text).expect("valid JSON");
    assert_eq!(parsed.get("format").and_then(|v| v.as_usize()), Some(4));
    assert_eq!(parsed.get("wire_mode").and_then(|v| v.as_str()), Some("compare"));
    let trials = parsed.get("trials").and_then(|t| t.as_arr()).expect("trials[]");
    assert_eq!(trials.len(), 2);
    assert_eq!(trials[1].get("wire").and_then(|v| v.as_str()), Some("binary"));
    assert_eq!(trials[1].get("cross_mismatches").and_then(|v| v.as_usize()), Some(0));

    // loadgen's --shutdown already stopped the server
    server.wait();
    let _ = std::fs::remove_dir_all(&dir);
}
