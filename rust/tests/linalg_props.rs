//! Property tests for the microkernel contract (DESIGN.md §2d).
//!
//! The register-blocked, cache-tiled kernels must be **bit-identical** —
//! 0 ULP, not approximately equal — to the frozen pre-microkernel
//! kernels (`linalg::microkernel::naive`) on every shape, including the
//! awkward ones that exercise every remainder path: single cells, prime
//! dims, and tile±1 around the MR/NR boundaries. On top of that sits the
//! PR-3 ownership contract (serial ↔ parallel bit-identity at every
//! thread count), invariance to the tile geometry itself (any MR×NR×KC
//! must produce the same bits), and the `data::pipeline` chunk-invariance
//! contract for SYRK accumulation.
//!
//! The matrices deliberately contain exact zeros: the naive kernels skip
//! `== 0.0` multipliers and the microkernels do not, and these tests pin
//! the claim that adding the skipped `±0.0` terms never changes a sum.

use gzk::exec::Pool;
use gzk::linalg::microkernel::{matmul_with_tile, naive, syrk_with_tile};
use gzk::linalg::{syrk_flat_into_p, Mat};
use gzk::rng::Rng;

/// Shape sweep around the register-tile boundaries: 1, primes, MR/NR −1,
/// exact, +1, and an off-tile large prime.
const DIMS: [usize; 8] = [1, 3, 4, 5, 7, 8, 9, 97];
/// Cheaper subset for the cubic sweeps of the secondary kernels.
const SUB: [usize; 5] = [1, 3, 5, 8, 97];

fn random(rng: &mut Rng, r: usize, c: usize) -> Mat {
    Mat::from_fn(r, c, |_, _| rng.normal())
}

/// ~30% exact zeros so the naive kernels' `== 0.0` skip branches fire.
fn random_sparse(rng: &mut Rng, r: usize, c: usize) -> Mat {
    Mat::from_fn(r, c, |_, _| {
        let v = rng.normal();
        if v.abs() < 0.4 {
            0.0
        } else {
            v
        }
    })
}

fn assert_bits_eq(a: &Mat, b: &Mat, ctx: &str) {
    assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()), "{ctx}: shape");
    for (i, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: cell {i}: {x} vs {y}");
    }
}

fn assert_bits_eq_vec(a: &[f64], b: &[f64], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: entry {i}: {x} vs {y}");
    }
}

#[test]
fn matmul_matches_naive_to_0_ulp() {
    let mut rng = Rng::new(0x5eed_0001);
    for m in DIMS {
        for k in DIMS {
            for n in DIMS {
                let a = random_sparse(&mut rng, m, k);
                let b = random_sparse(&mut rng, k, n);
                let ctx = format!("matmul m={m} k={k} n={n}");
                assert_bits_eq(&a.matmul(&b), &naive::matmul(&a, &b), &ctx);
            }
        }
    }
}

#[test]
fn matmul_nt_matches_naive_to_0_ulp() {
    let mut rng = Rng::new(0x5eed_0002);
    for m in SUB {
        for k in SUB {
            for n in SUB {
                let a = random_sparse(&mut rng, m, k);
                let b = random_sparse(&mut rng, n, k);
                let ctx = format!("matmul_nt m={m} k={k} n={n}");
                assert_bits_eq(&a.matmul_nt(&b), &naive::matmul_nt(&a, &b), &ctx);
            }
        }
    }
}

#[test]
fn matmul_tn_matches_naive_to_0_ulp() {
    let mut rng = Rng::new(0x5eed_0003);
    for m in SUB {
        for k in SUB {
            for n in SUB {
                let a = random_sparse(&mut rng, k, m);
                let b = random_sparse(&mut rng, k, n);
                let ctx = format!("matmul_tn m={m} k={k} n={n}");
                assert_bits_eq(&a.matmul_tn(&b), &naive::matmul_tn(&a, &b), &ctx);
            }
        }
    }
}

#[test]
fn syrk_matches_naive_to_0_ulp_and_accumulates() {
    let mut rng = Rng::new(0x5eed_0004);
    for rows in DIMS {
        for f in DIMS {
            let z = random_sparse(&mut rng, rows, f);
            let ctx = format!("syrk rows={rows} f={f}");
            let mut got = Mat::zeros(f, f);
            z.syrk_into(&mut got);
            let mut want = Mat::zeros(f, f);
            naive::syrk_into(&z, &mut want);
            assert_bits_eq(&got, &want, &ctx);
            // accumulating a second update composes identically too
            z.syrk_into(&mut got);
            naive::syrk_into(&z, &mut want);
            assert_bits_eq(&got, &want, &format!("{ctx} (accumulated)"));
        }
    }
}

#[test]
fn matvec_matches_naive_to_0_ulp() {
    let mut rng = Rng::new(0x5eed_0005);
    for m in DIMS {
        for n in DIMS {
            let a = random_sparse(&mut rng, m, n);
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let xt: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let ctx = format!("matvec m={m} n={n}");
            assert_bits_eq_vec(&a.matvec(&x), &naive::matvec(&a, &x), &ctx);
            let ctx = format!("matvec_t m={m} n={n}");
            assert_bits_eq_vec(&a.matvec_t(&xt), &naive::matvec_t(&a, &xt), &ctx);
        }
    }
}

#[test]
fn serial_parallel_bit_identity_across_threads() {
    let mut rng = Rng::new(0x5eed_0006);
    // straddle the MR/NR tile boundaries and the worker-chunk boundaries
    for (m, k, n) in [(1usize, 1usize, 1usize), (5, 3, 9), (31, 33, 32), (97, 41, 64)] {
        let a = random_sparse(&mut rng, m, k);
        let b = random_sparse(&mut rng, k, n);
        let bt = random_sparse(&mut rng, n, k);
        let at = random_sparse(&mut rng, k, m);
        let z = random_sparse(&mut rng, m, n);
        let x: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
        let xt: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let mm = a.matmul(&b);
        let nt = a.matmul_nt(&bt);
        let tn = at.matmul_tn(&b);
        let mv = a.matvec(&x);
        let mvt = a.matvec_t(&xt);
        let mut g = Mat::zeros(n, n);
        z.syrk_into(&mut g);
        for threads in [1usize, 2, 3, 8] {
            let pool = Pool::new(threads);
            let ctx = format!("m={m} k={k} n={n} threads={threads}");
            assert_bits_eq(&mm, &a.matmul_p(&b, &pool), &format!("matmul {ctx}"));
            assert_bits_eq(&nt, &a.matmul_nt_p(&bt, &pool), &format!("matmul_nt {ctx}"));
            assert_bits_eq(&tn, &at.matmul_tn_p(&b, &pool), &format!("matmul_tn {ctx}"));
            assert_bits_eq_vec(&mv, &a.matvec_p(&x, &pool), &format!("matvec {ctx}"));
            assert_bits_eq_vec(&mvt, &a.matvec_t_p(&xt, &pool), &format!("matvec_t {ctx}"));
            let mut gp = Mat::zeros(n, n);
            z.syrk_into_p(&mut gp, &pool);
            assert_bits_eq(&g, &gp, &format!("syrk {ctx}"));
        }
    }
}

#[test]
fn tile_geometry_never_changes_bits() {
    let mut rng = Rng::new(0x5eed_0007);
    let a = random_sparse(&mut rng, 37, 29);
    let b = random_sparse(&mut rng, 29, 41);
    let want = a.matmul(&b);
    let z = random_sparse(&mut rng, 45, 33);
    let mut gwant = Mat::zeros(33, 33);
    z.syrk_into(&mut gwant);
    for threads in [1usize, 3] {
        let pool = Pool::new(threads);
        for kc in [1usize, 3, 128, 1024] {
            let ctx = format!("threads={threads} kc={kc}");
            let got = matmul_with_tile::<4, 4>(&a, &b, kc, &pool);
            assert_bits_eq(&want, &got, &format!("4x4 {ctx}"));
            let got = matmul_with_tile::<8, 4>(&a, &b, kc, &pool);
            assert_bits_eq(&want, &got, &format!("8x4 {ctx}"));
            let got = matmul_with_tile::<8, 8>(&a, &b, kc, &pool);
            assert_bits_eq(&want, &got, &format!("8x8 {ctx}"));
            let mut g44 = Mat::zeros(33, 33);
            syrk_with_tile::<4, 4>(&z, kc, &pool, &mut g44);
            assert_bits_eq(&gwant, &g44, &format!("syrk 4x4 {ctx}"));
            let mut g88 = Mat::zeros(33, 33);
            syrk_with_tile::<8, 8>(&z, kc, &pool, &mut g88);
            assert_bits_eq(&gwant, &g88, &format!("syrk 8x8 {ctx}"));
        }
    }
}

/// The `data::pipeline` contract: accumulating `Z^T Z` from any row
/// chunking of the same stream must give bit-identical sums.
#[test]
fn syrk_chunk_invariance() {
    let mut rng = Rng::new(0x5eed_0008);
    let (rows, f) = (57usize, 19usize);
    let z = random_sparse(&mut rng, rows, f);
    let mut oneshot = Mat::zeros(f, f);
    syrk_flat_into_p(z.data(), f, &mut oneshot, &Pool::serial());
    for threads in [1usize, 3] {
        let pool = Pool::new(threads);
        for chunk in [1usize, 5, 19, rows] {
            let mut acc = Mat::zeros(f, f);
            for start in (0..rows).step_by(chunk) {
                let end = (start + chunk).min(rows);
                syrk_flat_into_p(&z.data()[start * f..end * f], f, &mut acc, &pool);
            }
            assert_bits_eq(&oneshot, &acc, &format!("chunk={chunk} threads={threads}"));
        }
    }
}

#[test]
fn degenerate_shapes() {
    // zero-depth reduction: output must be exactly zero, not NaN
    let a = Mat::zeros(4, 0);
    let b = Mat::zeros(0, 3);
    let c = a.matmul(&b);
    assert_eq!((c.rows(), c.cols()), (4, 3));
    assert!(c.data().iter().all(|v| v.to_bits() == 0));
    // zero-width output
    let d = Mat::zeros(3, 5).matmul(&Mat::zeros(5, 0));
    assert_eq!((d.rows(), d.cols()), (3, 0));
    // empty SYRK buffer accumulates nothing
    let mut g = Mat::zeros(6, 6);
    syrk_flat_into_p(&[], 6, &mut g, &Pool::serial());
    assert!(g.data().iter().all(|v| v.to_bits() == 0));
    // 1x1 end to end
    let s = Mat::from_vec(1, 1, vec![3.0]);
    assert_eq!(s.matmul(&s).data(), &[9.0]);
    assert_eq!(s.matvec(&[2.0]), vec![6.0]);
}
