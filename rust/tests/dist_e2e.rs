//! End-to-end tests of the distributed tier over real sockets: the TCP
//! scatter/gather fit is **bit-identical** to the in-process one-round
//! fit for every oblivious registry method, at any worker count, and
//! across an injected worker death or a hostile (protocol-violating)
//! worker; the replica proxy round-robins the serving protocol,
//! survives a replica death, surfaces the fleet-health stats, and fans
//! the wire shutdown out; and the loadgen replica sweep drives the whole
//! tier in-process.

use gzk::coordinator::{fit_one_round_source, Backend};
use gzk::data::SyntheticSource;
use gzk::dist::{
    run_worker, DataSpec, DistLeader, LeaderConfig, NetFit, Proxy, ProxyConfig, WorkerOptions,
};
use gzk::features::{BoundSpec, FeatureSpec, KernelSpec, Method};
use gzk::linalg::Mat;
use gzk::model::{set_run_data, FittedMap, Model, ModelStore, RidgeModel};
use gzk::rng::Rng;
use gzk::server::{wire, ClientConn, LoadgenConfig, Server, ServerConfig};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::time::Duration;

const N: usize = 600;
const CHUNK: usize = 128; // -> 5 shards over N = 600
const LAMBDA: f64 = 1e-2;
const SEED: u64 = 1;

/// `set_run_data` writes process-global run metadata that `save` reads;
/// tests in this binary run concurrently, so the set→save windows must
/// not interleave or the byte-identity comparison below gets flaky.
static RUN_DATA_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("gzk-dist-e2e-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn elevation_spec(method: Method) -> (BoundSpec, DataSpec) {
    let fspec = FeatureSpec::new(KernelSpec::Gaussian { bandwidth: 1.0 }, method, 32, SEED);
    let data = DataSpec { name: "elevation".to_string(), rows: N, seed: SEED };
    let src = SyntheticSource::by_name(&data.name, N, SEED).expect("elevation");
    (fspec.bind(src.dim()), data)
}

/// Run a distributed fit on loopback: a leader on an ephemeral port plus
/// one thread per entry of `worker_opts` running a real `run_worker`.
fn net_fit(spec: &BoundSpec, data: &DataSpec, worker_opts: &[WorkerOptions]) -> NetFit {
    let cfg = LeaderConfig {
        n_workers: worker_opts.len(),
        rows_per_shard: CHUNK,
        register_timeout: Duration::from_secs(30),
        shard_timeout: Duration::from_secs(30),
    };
    let leader = DistLeader::bind("127.0.0.1:0", cfg).expect("bind leader");
    let addr = leader.local_addr().expect("leader addr").to_string();
    let handles: Vec<_> = worker_opts
        .iter()
        .map(|opts| {
            let addr = addr.clone();
            let opts = opts.clone();
            std::thread::spawn(move || run_worker(&addr, &opts))
        })
        .collect();
    let fit = leader.run(spec, data, LAMBDA).expect("distributed fit");
    for h in handles {
        h.join().expect("worker thread").expect("worker run");
    }
    fit
}

fn weight_bits(w: &[f64]) -> Vec<u64> {
    w.iter().map(|v| v.to_bits()).collect()
}

fn local_fit(spec: &BoundSpec, data: &DataSpec) -> gzk::coordinator::DistributedFit {
    let src = SyntheticSource::by_name(&data.name, data.rows, data.seed).expect("source");
    fit_one_round_source(spec, &src, LAMBDA, 3, CHUNK, Backend::Native).expect("in-process fit")
}

#[test]
fn distributed_fit_is_bit_identical_for_every_oblivious_method() {
    for method in Method::registry() {
        if !method.is_oblivious() {
            continue; // data-dependent maps cannot be broadcast
        }
        let (spec, data) = elevation_spec(method);
        let local = local_fit(&spec, &data);
        let fit = net_fit(&spec, &data, &[WorkerOptions::default(), WorkerOptions::default()]);
        assert_eq!(fit.stats.n, N);
        assert_eq!(
            weight_bits(&fit.model.weights),
            weight_bits(&local.model.weights),
            "method {} drifted over TCP",
            spec.spec.method.name()
        );
    }
}

#[test]
fn distributed_fit_is_invariant_to_worker_count_and_artifacts_match_bytewise() {
    let (spec, data) = elevation_spec(Method::Gegenbauer { q: 6, s: 2 });
    let local = local_fit(&spec, &data);
    let one = net_fit(&spec, &data, &[WorkerOptions::default()]);
    let three = net_fit(
        &spec,
        &data,
        &[WorkerOptions::default(), WorkerOptions::default(), WorkerOptions::default()],
    );
    assert_eq!(weight_bits(&one.model.weights), weight_bits(&local.model.weights));
    assert_eq!(weight_bits(&three.model.weights), weight_bits(&local.model.weights));

    // the persisted artifact — not just the weights — is byte-identical,
    // so a store written by `gzk leader` is indistinguishable from one
    // written by the in-process fit
    let (dir_a, dir_b) = (fresh_dir("art-net"), fresh_dir("art-local"));
    let _guard = RUN_DATA_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    set_run_data(&data.name, data.rows);
    let net_model = RidgeModel::from_parts(
        FittedMap::rebuild(spec.clone(), None).expect("rebuild map"),
        three.model.clone(),
    );
    let local_model =
        RidgeModel::from_parts(FittedMap::rebuild(spec.clone(), None).expect("map"), local.model);
    let path_a = ModelStore::open(&dir_a).unwrap().save("ridge", &net_model).unwrap();
    let path_b = ModelStore::open(&dir_b).unwrap().save("ridge", &local_model).unwrap();
    assert_eq!(
        std::fs::read(&path_a).unwrap(),
        std::fs::read(&path_b).unwrap(),
        "artifact bytes diverged"
    );
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}

#[test]
fn leader_reassigns_shards_from_a_worker_that_dies_mid_fit() {
    let (spec, data) = elevation_spec(Method::Gegenbauer { q: 6, s: 2 });
    let local = local_fit(&spec, &data);
    // one worker drops its socket (no reply) when its second assignment
    // arrives; the survivor absorbs the reassigned shards
    let dying = WorkerOptions { die_after_shards: Some(1), ..WorkerOptions::default() };
    let fit = net_fit(&spec, &data, &[dying, WorkerOptions::default()]);
    assert!(fit.dead_workers >= 1, "the dying worker was never detected");
    assert!(fit.reassigned_shards >= 1, "its in-flight shard was never reassigned");
    assert_eq!(
        weight_bits(&fit.model.weights),
        weight_bits(&local.model.weights),
        "a worker death changed the model"
    );
}

#[test]
fn leader_abandons_a_hostile_worker_and_recovers_locally() {
    let (spec, data) = elevation_spec(Method::Gegenbauer { q: 6, s: 2 });
    let local = local_fit(&spec, &data);
    let cfg = LeaderConfig {
        n_workers: 1,
        rows_per_shard: CHUNK,
        register_timeout: Duration::from_secs(30),
        shard_timeout: Duration::from_secs(30),
    };
    let leader = DistLeader::bind("127.0.0.1:0", cfg).expect("bind leader");
    let addr = leader.local_addr().expect("leader addr").to_string();
    // a worker that registers correctly, then answers its assignment with
    // statistics for a different shard — a protocol violation the leader
    // must refuse (abandon + reassign), never merge
    let hostile = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(&addr).expect("connect");
        stream.write_all(b"{\"dist\":\"register\",\"proto\":1}\n").expect("register");
        let mut reader = BufReader::new(stream.try_clone().expect("clone"));
        let mut line = String::new();
        reader.read_line(&mut line).expect("job line");
        line.clear();
        reader.read_line(&mut line).expect("assign line");
        let lie = concat!(
            "{\"dist\":\"stats\",\"shard_id\":999,\"worker\":0,\"featurize_secs\":0.0,",
            "\"n\":128,\"yy\":0.0,\"b\":[0.0],\"g\":{\"rows\":1,\"cols\":1,\"data\":[0.0]}}\n"
        );
        stream.write_all(lie.as_bytes()).expect("lie");
        // the leader abandons us: the connection just closes
        line.clear();
        let _ = reader.read_line(&mut line);
    });
    let fit = leader.run(&spec, &data, LAMBDA).expect("fit survives a hostile worker");
    hostile.join().expect("hostile thread");
    assert_eq!(fit.dead_workers, 1);
    assert!(fit.reassigned_shards >= 1);
    // with no fleet left, every shard is leader-recovered — and the model
    // still comes out bit-identical
    assert_eq!(fit.recovered_shards, fit.n_shards);
    assert_eq!(weight_bits(&fit.model.weights), weight_bits(&local.model.weights));
}

// ---------------------------------------------------------------------------
// proxy + replicated serving
// ---------------------------------------------------------------------------

fn serving_store(tag: &str) -> (PathBuf, RidgeModel) {
    let dir = fresh_dir(tag);
    let spec = FeatureSpec::new(
        KernelSpec::Gaussian { bandwidth: 1.0 },
        Method::Gegenbauer { q: 5, s: 1 },
        16,
        11,
    )
    .bind(3);
    let mut rng = Rng::new(0xFEED);
    let x = Mat::from_fn(60, 3, |_, _| rng.normal() * 0.5);
    let y: Vec<f64> = (0..60).map(|i| x[(i, 0)] + 0.3 * x[(i, 2)]).collect();
    let model = RidgeModel::fit(spec, &x, &y, 1e-3).unwrap();
    let _guard = RUN_DATA_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    set_run_data("elevation", 60);
    ModelStore::open(&dir).unwrap().save("ridge", &model).unwrap();
    (dir, model)
}

fn predict_bits(model: &dyn Model, x: &[f64]) -> Vec<u64> {
    let out = model.predict(&Mat::from_vec(1, x.len(), x.to_vec()));
    out.row(0).iter().map(|v| v.to_bits()).collect()
}

fn test_proxy_config() -> ProxyConfig {
    ProxyConfig { probe_interval: Duration::from_millis(50), ..ProxyConfig::default() }
}

#[test]
fn proxy_balances_replicas_survives_a_death_and_fans_out_shutdown() {
    let (dir, model) = serving_store("proxy");
    let cfg = ServerConfig { poll: Duration::from_millis(25), ..ServerConfig::default() };
    let s1 = Server::start(&dir, "127.0.0.1:0", cfg).unwrap();
    let s2 = Server::start(&dir, "127.0.0.1:0", cfg).unwrap();
    let replicas = vec![s1.local_addr().to_string(), s2.local_addr().to_string()];
    let proxy = Proxy::start("127.0.0.1:0", replicas, test_proxy_config()).unwrap();
    let addr = proxy.local_addr().to_string();

    // predictions through the proxy are bit-identical to the local model,
    // across enough requests that round-robin touches both replicas
    let mut conn = ClientConn::connect(&addr).unwrap();
    let probes = [[0.25, -0.7, 0.1], [1.0, 0.9, -0.4], [-1.1, 0.05, 0.6], [0.0, 0.0, 1.0]];
    for x in probes.iter().cycle().take(12) {
        let r = conn.roundtrip(&wire::predict_request(Some("ridge"), x)).unwrap();
        assert!(r.ok, "{r:?}");
        let bits: Vec<u64> = r.y().unwrap().iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits, predict_bits(&model, x));
    }

    // the fleet-health stats (uptime, reload count, cumulative rejects)
    // surface through the proxy — this is what its prober logs
    let stats = conn.roundtrip(&wire::cmd_request("stats")).unwrap();
    assert!(stats.ok);
    for field in ["\"uptime_s\":", "\"reloads\":", "\"total_rejects\":"] {
        assert!(stats.raw.contains(field), "missing {field}: {}", stats.raw);
    }
    // the proxy splices its own per-replica counters into the same reply
    let proxy_stats = stats.body.get("proxy").expect("stats reply carries a proxy section");
    let replica_rows = proxy_stats.get("replicas").and_then(|r| r.as_arr()).unwrap();
    assert_eq!(replica_rows.len(), 2);
    for row in replica_rows {
        for field in ["addr", "healthy", "forwarded", "strikes", "ejections", "retries"] {
            assert!(row.get(field).is_some(), "replica row missing {field}: {}", stats.raw);
        }
    }

    // `metrics` is answered by the proxy itself, never forwarded: the
    // snapshot names this proxy's per-replica registry counters
    let metrics = conn.roundtrip(&wire::cmd_request("metrics")).unwrap();
    assert!(metrics.ok, "{metrics:?}");
    let snap = metrics.body.get("metrics").expect("metrics reply carries a snapshot");
    assert!(snap.get("counters").is_some() && snap.get("ladder_bounds_s").is_some());
    assert!(
        metrics.raw.contains("proxy.replica."),
        "per-replica counters missing from: {}",
        metrics.raw
    );

    // kill one replica out from under the proxy: requests keep succeeding
    // over the survivor (transport failures strike the dead replica out)
    s1.shutdown();
    let _ = s1.wait();
    for x in probes.iter().cycle().take(8) {
        let r = conn.roundtrip(&wire::predict_request(Some("ridge"), x)).unwrap();
        assert!(r.ok, "failover lost a request: {r:?}");
        let bits: Vec<u64> = r.y().unwrap().iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits, predict_bits(&model, x));
    }

    // one loopback shutdown line tears down the whole tier
    let bye = conn.roundtrip(&wire::cmd_request("shutdown")).unwrap();
    assert!(bye.ok, "{bye:?}");
    let summary = proxy.wait();
    assert!(summary.contains("forwarded"), "{summary}");
    let _ = s2.wait(); // the broadcast reached the surviving replica
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn loadgen_replica_sweep_scales_the_serving_tier_in_process() {
    let (dir, _model) = serving_store("sweep");
    let cfg = LoadgenConfig {
        addr: String::new(), // no direct target: sweep only
        clients: vec![2],
        requests_per_client: 25,
        dataset: Some("elevation".to_string()),
        store: Some(dir.clone()),
        seed: 7,
        replica_sweep: vec![1, 2],
        ..LoadgenConfig::default()
    };
    let report = gzk::server::loadgen::run(&cfg).expect("sweep runs");
    assert!(report.verified, "a store was supplied, so replies must be verified");
    assert_eq!(report.replica_trials.len(), 2);
    assert_eq!(report.replica_trials[0].replicas, 1);
    assert_eq!(report.replica_trials[1].replicas, 2);
    for r in &report.replica_trials {
        assert_eq!(r.trial.clients, 2);
        assert!(r.trial.requests > 0);
        assert_eq!(r.trial.mismatches, 0, "sweep replies diverged from the artifact");
    }
    assert_eq!(report.mismatches(), 0);

    // the JSON lands with the replica section populated
    let json = dir.join("BENCH_sweep.json");
    report.write_json(&json).expect("write json");
    let text = std::fs::read_to_string(&json).unwrap();
    assert!(text.contains("\"replica_sweep\":[{\"replicas\":1,"), "{text}");
    let _ = std::fs::remove_dir_all(&dir);
}
