//! Integration: the AOT jax/Pallas PJRT path must agree with the native
//! rust featurizer and with the python ref oracle (transitively, since the
//! python tests pin pallas == ref).
//!
//! Requires `make artifacts` to have run; tests are skipped (pass
//! trivially) when the manifest is missing so `cargo test` works in a
//! fresh checkout.

use gzk::features::{Featurizer, GegenbauerFeatures, RadialTable};
use gzk::linalg::Mat;
use gzk::rng::Rng;
use gzk::runtime::{default_artifact_dir, Runtime};

fn runtime_or_skip() -> Option<Runtime> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("skipping PJRT test: built without the `pjrt` feature");
        return None;
    }
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping PJRT test: no artifacts at {dir:?} (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open(&dir).expect("open runtime"))
}

#[test]
fn featurize_matches_native_d3() {
    let Some(rt) = runtime_or_skip() else { return };
    let art = rt.manifest().find_featurize("gaussian", 3).expect("d3 artifact").clone();
    let table = RadialTable::gaussian(3, art.q, art.s);
    let m = art.block_m * 2; // two direction chunks
    let native = GegenbauerFeatures::new(table, m, 424242);
    let mut rng = Rng::new(9);
    let x = Mat::from_fn(50, 3, |_, _| rng.normal() * 0.7); // odd row count -> padding path
    let z_native = native.featurize(&x);
    let z_pjrt = rt.featurize("gaussian", &x, native.directions()).expect("pjrt featurize");
    assert_eq!(z_pjrt.rows(), 50);
    assert_eq!(z_pjrt.cols(), m * art.s);
    // f32 vs f64 tolerance
    let scale = z_native.data().iter().fold(0.0f64, |a, &b| a.max(b.abs()));
    let err = z_native.max_abs_diff(&z_pjrt);
    assert!(err < 1e-4 * scale.max(1.0), "max diff {err} (scale {scale})");
}

#[test]
fn featurize_matches_native_d9() {
    let Some(rt) = runtime_or_skip() else { return };
    let art = rt.manifest().find_featurize("gaussian", 9).expect("d9 artifact").clone();
    let table = RadialTable::gaussian(9, art.q, art.s);
    let native = GegenbauerFeatures::new(table, art.block_m, 77);
    let mut rng = Rng::new(10);
    let x = Mat::from_fn(300, 9, |_, _| rng.normal() * 0.3); // > one row block
    let z_native = native.featurize(&x);
    let z_pjrt = rt.featurize("gaussian", &x, native.directions()).expect("pjrt featurize");
    let err = z_native.max_abs_diff(&z_pjrt);
    assert!(err < 1e-4, "max diff {err}");
}

#[test]
fn gram_from_pjrt_features_approximates_kernel() {
    let Some(rt) = runtime_or_skip() else { return };
    let art = rt.manifest().find_featurize("gaussian", 3).unwrap().clone();
    let table = RadialTable::gaussian(3, art.q, art.s);
    let m = art.block_m * 8;
    let native = GegenbauerFeatures::new(table, m, 5);
    let mut rng = Rng::new(11);
    let x = Mat::from_fn(24, 3, |_, _| rng.normal() * 0.5);
    let z = rt.featurize("gaussian", &x, native.directions()).unwrap();
    let k_hat = z.matmul_nt(&z);
    let k = gzk::kernels::Kernel::Gaussian { bandwidth: 1.0 }.gram(&x);
    let err = k_hat.max_abs_diff(&k);
    assert!(err < 0.25, "gram error {err}");
}

#[test]
fn krr_solve_artifact_matches_native_cholesky() {
    let Some(rt) = runtime_or_skip() else { return };
    let f = rt.manifest().krr_solve.first().expect("krr artifact").f;
    let mut rng = Rng::new(12);
    let a = Mat::from_fn(f, f, |_, _| rng.normal() / (f as f64).sqrt());
    let mut g = a.matmul_tn(&a);
    g.symmetrize_from_upper();
    let b: Vec<f64> = (0..f).map(|_| rng.normal()).collect();
    let lambda = 0.5;
    let w_pjrt = rt.krr_solve(&g, &b, lambda).expect("pjrt solve");
    let mut g_reg = g.clone();
    g_reg.add_diag(lambda);
    let chol = gzk::linalg::Cholesky::new(&g_reg).unwrap();
    let w_native = chol.solve(&b);
    // f32 solve tolerance on a well-conditioned system
    let wmax = w_native.iter().fold(0.0f64, |a, &b| a.max(b.abs()));
    for (i, (p, n)) in w_pjrt.iter().zip(&w_native).enumerate() {
        assert!((p - n).abs() < 5e-3 * wmax.max(1.0), "w[{i}]: {p} vs {n}");
    }
}

#[test]
fn all_manifest_featurize_artifacts_load_and_run() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(13);
    for art in rt.manifest().featurize.clone() {
        let x = Mat::from_fn(8, art.d, |_, _| rng.normal() * 0.4);
        // table family must match the artifact — this also cross-checks the
        // rust Gauss-Jacobi NTK coefficients against scipy's (python side)
        let table = match art.family.as_str() {
            "gaussian" => RadialTable::gaussian(art.d, art.q, art.s),
            "ntk" => RadialTable::ntk(art.d, art.q, 2),
            other => panic!("unknown artifact family {other}"),
        };
        let native = GegenbauerFeatures::new(table, art.block_m, 1000 + art.d as u64);
        let z = rt
            .featurize(&art.family, &x, native.directions())
            .unwrap_or_else(|e| panic!("{}: {e}", art.name));
        assert_eq!(z.cols(), art.block_m * art.s, "{}", art.name);
        assert!(z.data().iter().all(|v| v.is_finite()), "{}", art.name);
        let z_native = native.featurize(&x);
        let err = z_native.max_abs_diff(&z);
        assert!(err < 2e-4, "{}: {err}", art.name);
    }
}
