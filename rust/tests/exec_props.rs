//! Determinism properties of the parallel execution engine (DESIGN.md
//! §"Execution model"): every pool-backed path must be **bit-identical**
//! across thread counts {1, 2, 3, 8} — featurization, the parallel linalg
//! kernels, and a full fit → predict pipeline — for every method in
//! `Method::registry()`. This is the contract that lets the whole stack
//! adopt the pool without perturbing any numeric result.

use gzk::exec::Pool;
use gzk::features::{FeatureSpec, Featurizer, KernelSpec, Method};
use gzk::kmeans::kmeans_with;
use gzk::kpca::KernelPca;
use gzk::krr::RidgeStats;
use gzk::linalg::Mat;
use gzk::rng::Rng;

const THREADS: [usize; 4] = [1, 2, 3, 8];

fn dataset(n: usize, d: usize, seed: u64) -> (Mat, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let x = Mat::from_fn(n, d, |_, _| rng.normal() * 0.6);
    let y: Vec<f64> =
        (0..n).map(|i| (2.0 * x[(i, 0)]).sin() + x[(i, 1)] + 0.02 * rng.normal()).collect();
    (x, y)
}

#[test]
fn featurize_par_bit_identical_across_thread_counts_for_every_method() {
    // odd row count on purpose: chunk boundaries never divide evenly
    let (x, _) = dataset(61, 3, 0xE1);
    for method in Method::registry() {
        // bandwidth != 1 exercises the InputScaled wrapper too
        let spec = FeatureSpec::new(KernelSpec::Gaussian { bandwidth: 1.2 }, method, 64, 9);
        let feat = spec.build_with_data(&x);
        let z = feat.featurize(&x);
        for t in THREADS {
            let zp = feat.featurize_par(&x, &Pool::new(t));
            assert_eq!(z, zp, "{}: featurize_par({t}) differs from serial", feat.name());
        }
        // explicit pools wider than the row count are honored, not
        // silently serialized — and still bit-identical
        let tiny = x.row_block(0, 3);
        let z_tiny = feat.featurize(&tiny);
        assert_eq!(z_tiny, feat.featurize_par(&tiny, &Pool::new(8)), "{}", feat.name());
    }
}

#[test]
fn parallel_syrk_bit_identical_across_thread_counts() {
    let (z, y) = dataset(83, 5, 0xE2);
    // reference: the serial absorb (single-thread pool)
    let mut serial = RidgeStats::new(z.cols());
    serial.absorb_with(&z, &y, &Pool::serial());
    for t in THREADS {
        let mut par = RidgeStats::new(z.cols());
        par.absorb_with(&z, &y, &Pool::new(t));
        assert_eq!(serial.g, par.g, "G differs at {t} threads");
        assert_eq!(serial.b, par.b, "b differs at {t} threads");
        assert_eq!((serial.n, serial.yy), (par.n, par.yy), "counters differ at {t} threads");
        // and the raw kernel agrees with the absorb path
        let mut g = Mat::zeros(z.cols(), z.cols());
        z.syrk_into_p(&mut g, &Pool::new(t));
        assert_eq!(serial.g, g, "syrk_into_p differs at {t} threads");
    }
}

#[test]
fn full_fit_predict_bit_identical_across_thread_counts_for_every_method() {
    // the end-to-end property: featurize -> absorb -> solve -> predict,
    // run entirely on an explicit pool, must produce byte-equal
    // predictions at every width for every registry method (including
    // data-dependent Nystrom, built from the training rows)
    let (x, y) = dataset(57, 3, 0xE3);
    let (x_new, _) = dataset(19, 3, 0xE4);
    for method in Method::registry() {
        let spec = FeatureSpec::new(KernelSpec::Gaussian { bandwidth: 1.0 }, method, 48, 11);
        let feat = spec.build_with_data(&x);
        let fit_predict = |pool: &Pool| -> Vec<f64> {
            let z = feat.featurize_par(&x, pool);
            let mut stats = RidgeStats::new(z.cols());
            stats.absorb_with(&z, &y, pool);
            let model = stats.solve(1e-2);
            let zt = feat.featurize_par(&x_new, pool);
            model.predict_with(&zt, pool)
        };
        let reference = fit_predict(&Pool::serial());
        for t in THREADS {
            let pred = fit_predict(&Pool::new(t));
            assert_eq!(
                reference,
                pred,
                "{}: fit -> predict differs at {t} threads",
                feat.name()
            );
        }
    }
}

#[test]
fn kmeans_and_kpca_bit_identical_across_thread_counts() {
    let (x, _) = dataset(70, 4, 0xE5);
    let spec = FeatureSpec::new(
        KernelSpec::Gaussian { bandwidth: 1.0 },
        Method::Gegenbauer { q: 6, s: 2 },
        32,
        13,
    );
    let feat = spec.build(4);
    let z = feat.featurize(&x);
    let ref_km = kmeans_with(&z, 3, 30, 7, &Pool::serial());
    let ref_pca = KernelPca::fit_with(&z, 3, &Pool::serial());
    let ref_emb = ref_pca.transform_with(&z, &Pool::serial());
    for t in THREADS {
        let pool = Pool::new(t);
        let km = kmeans_with(&z, 3, 30, 7, &pool);
        assert_eq!(ref_km.assignments, km.assignments, "assignments differ at {t} threads");
        assert_eq!(ref_km.objective, km.objective, "objective differs at {t} threads");
        assert_eq!(ref_km.centroids, km.centroids, "centroids differ at {t} threads");
        let pca = KernelPca::fit_with(&z, 3, &pool);
        assert_eq!(ref_pca.eigenvalues, pca.eigenvalues, "eigenvalues differ at {t} threads");
        assert_eq!(ref_emb, pca.transform_with(&z, &pool), "embedding differs at {t} threads");
    }
}
