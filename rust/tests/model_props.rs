//! Property tests on the model artifact subsystem: for **every** registry
//! method — oblivious and data-dependent — and every model kind,
//! `fit → save → load → predict` must be **bit-identical** to predicting
//! with the in-memory model. The codec writes floats in shortest
//! round-trip form and the seed as a decimal string (seed-safe, like
//! `spec_props` requires of the wire codec), so an artifact is a perfect
//! substitute for the process that produced it.

use gzk::features::{FeatureSpec, KernelSpec, Method};
use gzk::linalg::Mat;
use gzk::model::{from_artifact, KmeansModel, KpcaModel, Model, ModelKind, ModelStore, RidgeModel};
use gzk::rng::Rng;

fn dataset(n: usize, d: usize, seed: u64) -> (Mat, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let x = Mat::from_fn(n, d, |_, _| rng.normal() * 0.6);
    let y: Vec<f64> =
        (0..n).map(|i| (2.0 * x[(i, 0)]).sin() + x[(i, 1)] + 0.02 * rng.normal()).collect();
    (x, y)
}

/// The three model kinds fitted through one spec (big seed on the last
/// method exercises the u64 range through the artifact).
fn fit_all(spec: &gzk::features::BoundSpec, x: &Mat, y: &[f64]) -> Vec<Box<dyn Model>> {
    vec![
        Box::new(RidgeModel::fit(spec.clone(), x, y, 1e-2).expect("ridge fit")),
        Box::new(KmeansModel::fit(spec.clone(), x, 3, 40).expect("kmeans fit")),
        Box::new(KpcaModel::fit(spec.clone(), x, 2).expect("kpca fit")),
    ]
}

#[test]
fn artifact_roundtrip_is_bit_identical_for_every_registry_method() {
    let (x, y) = dataset(60, 3, 50);
    let mut rng = Rng::new(51);
    let x_new = Mat::from_fn(15, 3, |_, _| rng.normal() * 0.6);
    for (i, method) in Method::registry().into_iter().enumerate() {
        // u64::MAX-range seed: the decimal-string codec must carry it
        let seed = u64::MAX - 17 * (i as u64 + 1);
        let spec = FeatureSpec::new(
            KernelSpec::Gaussian { bandwidth: 1.0 },
            method,
            48,
            seed,
        )
        .bind(3);
        for model in fit_all(&spec, &x, &y) {
            let text = model.to_artifact();
            let loaded = from_artifact(&text)
                .unwrap_or_else(|e| panic!("{} {}: {e}", spec.spec.method.name(), model.kind().name()));
            let tag = format!("{} {}", spec.spec.method.name(), model.kind().name());
            assert_eq!(loaded.kind(), model.kind(), "{tag}");
            assert_eq!(loaded.feature_spec(), model.feature_spec(), "{tag}");
            assert_eq!(loaded.output_dim(), model.output_dim(), "{tag}");
            // THE acceptance property: bit-identical prediction
            assert_eq!(loaded.predict(&x_new), model.predict(&x_new), "{tag}");
            assert_eq!(loaded.predict(&x), model.predict(&x), "{tag} (training rows)");
            // and the codec is a fixed point: re-serialization is byte-equal
            assert_eq!(loaded.to_artifact(), text, "{tag} re-serialization drifted");
        }
    }
}

#[test]
fn store_saves_loads_and_lists_every_kind() {
    let dir = std::env::temp_dir().join(format!("gzk-model-props-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = ModelStore::open(&dir).expect("open store");
    let (x, y) = dataset(50, 3, 70);
    let spec = FeatureSpec::new(
        KernelSpec::Gaussian { bandwidth: 1.0 },
        Method::Gegenbauer { q: 8, s: 2 },
        64,
        71,
    )
    .bind(3);
    let models = fit_all(&spec, &x, &y);
    for model in &models {
        store.save(model.kind().name(), model.as_ref()).expect("save");
    }
    // manifest lists all three, sorted by name
    let entries = store.entries().expect("entries");
    let names: Vec<&str> = entries.iter().map(|e| e.name.as_str()).collect();
    assert_eq!(names, vec!["kmeans", "kpca", "ridge"]);
    // loading reproduces each model bit-for-bit
    let mut rng = Rng::new(72);
    let probe = Mat::from_fn(9, 3, |_, _| rng.normal() * 0.5);
    for model in &models {
        let loaded = store.load(model.kind().name()).expect("load");
        assert_eq!(loaded.predict(&probe), model.predict(&probe), "{}", model.kind().name());
    }
    // overwriting a name replaces, not duplicates
    let again = RidgeModel::fit(spec.clone(), &x, &y, 0.5).unwrap();
    store.save("ridge", &again).expect("resave");
    assert_eq!(store.entries().unwrap().len(), 3);
    let reloaded = store.load("ridge").expect("reload");
    assert_eq!(reloaded.predict(&probe), Model::predict(&again, &probe));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn nystrom_artifact_carries_its_landmarks() {
    // the data-dependent half: an artifact must reconstruct the Nystrom
    // map WITHOUT the training data — the landmarks travel inside
    let (x, y) = dataset(40, 3, 90);
    let spec = FeatureSpec::new(
        KernelSpec::Gaussian { bandwidth: 1.0 },
        Method::Nystrom { lambda: 1e-3 },
        16,
        91,
    )
    .bind(3);
    let model = RidgeModel::fit(spec, &x, &y, 1e-2).unwrap();
    let text = model.to_artifact();
    assert!(text.contains("nystrom_landmarks"), "landmarks missing from artifact");
    let loaded = from_artifact(&text).unwrap();
    let mut rng = Rng::new(92);
    let probe = Mat::from_fn(7, 3, |_, _| rng.normal() * 0.5);
    assert_eq!(loaded.predict(&probe), Model::predict(&model, &probe));
    // stripping the landmarks must fail cleanly, not rebuild wrongly
    let start = text.find(",\"nystrom_landmarks\"").unwrap();
    let end = text[start + 1..].find(",\"state\"").unwrap() + start + 1;
    let stripped = format!("{}{}", &text[..start], &text[end..]);
    let err = from_artifact(&stripped).unwrap_err();
    assert!(err.contains("landmark"), "{err}");
}

#[test]
fn artifact_rejects_tampering() {
    let (x, y) = dataset(30, 3, 95);
    let spec = FeatureSpec::new(
        KernelSpec::Gaussian { bandwidth: 1.0 },
        Method::Fourier,
        32,
        96,
    )
    .bind(3);
    let model = RidgeModel::fit(spec, &x, &y, 1e-2).unwrap();
    let text = model.to_artifact();
    // future format
    let future = text.replacen("\"format\":1", "\"format\":2", 1);
    assert!(from_artifact(&future).unwrap_err().contains("format 2"));
    // unknown kind
    let alien = text.replacen("\"kind\":\"ridge\"", "\"kind\":\"svm\"", 1);
    assert!(from_artifact(&alien).unwrap_err().contains("svm"));
    // weight count no longer matches the spec'd feature dimension
    let truncated = text.replacen("\"weights\":[", "\"weights\":[0.0,", 1);
    assert!(from_artifact(&truncated).is_err());
    // model kind / state mismatch: ridge state under a kmeans kind
    let crossed = text.replacen("\"kind\":\"ridge\"", "\"kind\":\"kmeans\"", 1);
    assert!(from_artifact(&crossed).is_err());
}

#[test]
fn kinds_report_consistent_output_dims() {
    let (x, y) = dataset(40, 3, 97);
    let spec = FeatureSpec::new(
        KernelSpec::Gaussian { bandwidth: 1.0 },
        Method::Gegenbauer { q: 6, s: 2 },
        48,
        98,
    )
    .bind(3);
    for model in fit_all(&spec, &x, &y) {
        let out = model.predict(&x);
        assert_eq!(out.rows(), x.rows(), "{}", model.kind().name());
        assert_eq!(out.cols(), model.output_dim(), "{}", model.kind().name());
        match model.kind() {
            ModelKind::Ridge | ModelKind::Kmeans => assert_eq!(model.output_dim(), 1),
            ModelKind::Kpca => assert_eq!(model.output_dim(), 2),
        }
    }
}
