//! End-to-end distributed tracing through real processes: a traced
//! `predict` sent through a `gzk proxy` into a `gzk server` replica must
//! (a) leave spans carrying the SAME trace ID in both processes'
//! `--trace-out` files, over the JSON wire and over GZF2 binary frames,
//! (b) produce replies byte-identical to the untraced twin of every
//! request (tracing is read-only on the wire), and (c) stitch into one
//! Perfetto timeline via the `gzk trace-merge` subcommand, with each
//! process on its own lane.

use gzk::features::{FeatureSpec, KernelSpec, Method};
use gzk::linalg::Mat;
use gzk::model::{set_run_data, Model, ModelStore, RidgeModel};
use gzk::rng::Rng;
use gzk::runtime::Json;
use gzk::server::{frame, wire, ClientConn};
use std::net::TcpStream;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::Duration;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_gzk"))
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("gzk-trace-e2e-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn tmp_file(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("gzk-trace-e2e-{}-{tag}", std::process::id()))
}

/// Fit a small ridge model into a fresh store (the replica's fleet).
fn serving_store(tag: &str) -> (PathBuf, RidgeModel) {
    let dir = fresh_dir(tag);
    let spec = FeatureSpec::new(
        KernelSpec::Gaussian { bandwidth: 1.0 },
        Method::Gegenbauer { q: 5, s: 1 },
        16,
        11,
    )
    .bind(3);
    let mut rng = Rng::new(0xFEED);
    let x = Mat::from_fn(60, 3, |_, _| rng.normal() * 0.5);
    let y: Vec<f64> = (0..60).map(|i| x[(i, 0)] + 0.3 * x[(i, 2)]).collect();
    let model = RidgeModel::fit(spec, &x, &y, 1e-3).unwrap();
    set_run_data("elevation", 60);
    ModelStore::open(&dir).unwrap().save("ridge", &model).unwrap();
    (dir, model)
}

/// Kill the child on panic so a failed assertion never leaks a listener.
struct ChildGuard(Option<Child>);

impl ChildGuard {
    fn wait(&mut self) -> std::process::ExitStatus {
        self.0.take().expect("child already waited").wait().expect("wait on child")
    }
}

impl Drop for ChildGuard {
    fn drop(&mut self) {
        if let Some(mut c) = self.0.take() {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

fn spawn_gzk(args: &[&str]) -> ChildGuard {
    let child = bin()
        .args(args)
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .unwrap_or_else(|e| panic!("spawn gzk {args:?}: {e}"));
    ChildGuard(Some(child))
}

fn wait_listening(addr: &str) {
    for _ in 0..400 {
        if TcpStream::connect(addr).is_ok() {
            return;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    panic!("{addr} never started listening");
}

/// Spans in a `--trace-out` document carrying `args.trace == tid`.
fn span_names_for_trace(doc: &Json, tid: u64) -> Vec<String> {
    let want = format!("{tid}");
    doc.get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array")
        .iter()
        .filter(|e| {
            e.get("args").and_then(|a| a.get("trace")).and_then(Json::as_str)
                == Some(want.as_str())
        })
        .filter_map(|e| e.get("name").and_then(Json::as_str).map(str::to_string))
        .collect()
}

#[test]
fn traced_predicts_stitch_across_proxy_and_replica_and_replies_stay_bit_identical() {
    let (dir, model) = serving_store("stitch");
    let server_trace = tmp_file("server-trace.json");
    let proxy_trace = tmp_file("proxy-trace.json");
    let merged = tmp_file("merged-trace.json");
    for f in [&server_trace, &proxy_trace, &merged] {
        let _ = std::fs::remove_file(f);
    }

    // pid-derived ports: unique per test process, no listener collisions
    // between concurrently running test binaries
    let base = 21000 + (std::process::id() % 30000) as u16;
    let server_addr = format!("127.0.0.1:{base}");
    let proxy_addr = format!("127.0.0.1:{}", base + 1);

    let mut server = spawn_gzk(&[
        "server",
        "--store",
        dir.to_str().unwrap(),
        "--addr",
        &server_addr,
        "--poll-ms",
        "50",
        "--trace-out",
        server_trace.to_str().unwrap(),
    ]);
    wait_listening(&server_addr);
    let mut proxy = spawn_gzk(&[
        "proxy",
        "--replicas",
        &server_addr,
        "--listen",
        &proxy_addr,
        "--trace-out",
        proxy_trace.to_str().unwrap(),
    ]);
    wait_listening(&proxy_addr);

    // two client-minted trace IDs: one rides the JSON "tid" field, one
    // the GZF2 frame-header slot
    const TID_JSON: u64 = 0x5EED_0000_0000_0001;
    const TID_BIN: u64 = 0x5EED_0000_0000_0002;
    let x = [0.25, -0.7, 0.1];
    let local_bits: Vec<u64> = {
        let out = model.predict(&Mat::from_vec(1, x.len(), x.to_vec()));
        out.row(0).iter().map(|v| v.to_bits()).collect()
    };

    // --- JSON wire: traced and untraced replies are byte-identical ---
    let mut conn = ClientConn::connect(&proxy_addr).unwrap();
    let plain = conn.roundtrip(&wire::predict_request(Some("ridge"), &x)).unwrap();
    assert!(plain.ok, "{plain:?}");
    let traced =
        conn.roundtrip(&wire::predict_request_traced(Some("ridge"), &x, TID_JSON)).unwrap();
    assert!(traced.ok, "{traced:?}");
    assert_eq!(plain.raw, traced.raw, "a JSON reply must never reveal its request's trace ID");
    let bits: Vec<u64> = traced.y().unwrap().iter().map(|v| v.to_bits()).collect();
    assert_eq!(bits, local_bits, "traced predict drifted from the local model");

    // --- binary wire: the proxy negotiates GZF2 (v2) and the traced
    // frame's reply is byte-identical to the untraced GZF1 twin ---
    let mut bconn = ClientConn::connect(&proxy_addr).unwrap();
    let v2 = bconn.upgrade_binary_v2().unwrap();
    assert!(v2, "a new proxy must ack the v2 binary offer");
    let payload = frame::predict_payload(Some("ridge"), &x);
    let plain_frame = bconn.roundtrip_frame(&frame::frame(&payload)).unwrap();
    assert_eq!(frame::reply_status(&plain_frame), Some(frame::ST_OK));
    let traced_frame = bconn.roundtrip_frame(&frame::frame_traced(&payload, TID_BIN)).unwrap();
    assert_eq!(
        plain_frame, traced_frame,
        "a binary reply must never reveal its request's trace ID"
    );

    // tear the tier down over the wire: the proxy fans shutdown out to
    // the replica, both processes exit cleanly and write their traces
    let bye = conn.roundtrip(&wire::cmd_request("shutdown")).unwrap();
    assert!(bye.ok, "{bye:?}");
    drop(conn);
    drop(bconn);
    assert!(proxy.wait().success(), "proxy exited uncleanly");
    assert!(server.wait().success(), "server exited uncleanly");

    // --- both processes hold spans for BOTH client-minted trace IDs ---
    let proxy_doc = Json::parse(&std::fs::read_to_string(&proxy_trace).unwrap()).unwrap();
    let server_doc = Json::parse(&std::fs::read_to_string(&server_trace).unwrap()).unwrap();
    assert_eq!(proxy_doc.get("process_name").and_then(Json::as_str), Some("gzk proxy"));
    assert_eq!(server_doc.get("process_name").and_then(Json::as_str), Some("gzk server"));
    for tid in [TID_JSON, TID_BIN] {
        let fwd = span_names_for_trace(&proxy_doc, tid);
        assert!(
            fwd.iter().any(|n| n == "forward"),
            "proxy trace lacks a forward span for {tid:#x}: {fwd:?}"
        );
        let srv = span_names_for_trace(&server_doc, tid);
        assert!(
            srv.iter().any(|n| n == "predict"),
            "server trace lacks a predict span for {tid:#x}: {srv:?}"
        );
    }
    // the untraced JSON predict was minted a trace ID at the proxy
    // ingress: some forwarded span beyond the two client-minted ones
    let proxy_events = proxy_doc.get("traceEvents").and_then(Json::as_arr).unwrap();
    let (t_json, t_bin) = (TID_JSON.to_string(), TID_BIN.to_string());
    let minted = proxy_events
        .iter()
        .filter_map(|e| e.get("args").and_then(|a| a.get("trace")).and_then(Json::as_str))
        .filter(|t| *t != t_json.as_str() && *t != t_bin.as_str())
        .count();
    assert!(minted >= 1, "the proxy never minted an ingress trace ID for the untraced predict");

    // --- `gzk trace-merge` stitches the two files into one timeline ---
    let out = bin()
        .args([
            "trace-merge",
            "--inputs",
            &format!("{},{}", proxy_trace.display(), server_trace.display()),
            "--out",
            merged.to_str().unwrap(),
        ])
        .output()
        .expect("spawn gzk trace-merge");
    assert!(
        out.status.success(),
        "trace-merge failed\nstderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let merged_doc = Json::parse(&std::fs::read_to_string(&merged).unwrap()).unwrap();
    let events = merged_doc.get("traceEvents").and_then(Json::as_arr).unwrap();
    // each input file became its own process lane
    let lanes: Vec<&str> = events
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
        .filter_map(|e| e.get("args").and_then(|a| a.get("name")).and_then(Json::as_str))
        .collect();
    assert!(lanes.iter().any(|l| l.contains("gzk proxy")), "{lanes:?}");
    assert!(lanes.iter().any(|l| l.contains("gzk server")), "{lanes:?}");
    // and every client-minted trace ID spans BOTH lanes of the merge
    for tid in [TID_JSON, TID_BIN] {
        let want = format!("{tid}");
        let pids: std::collections::BTreeSet<u64> = events
            .iter()
            .filter(|e| {
                e.get("args").and_then(|a| a.get("trace")).and_then(Json::as_str)
                    == Some(want.as_str())
            })
            .filter_map(|e| e.get("pid").and_then(Json::as_f64))
            .map(|p| p as u64)
            .collect();
        assert_eq!(pids.len(), 2, "trace {tid:#x} must appear in both processes' lanes");
    }

    for f in [&server_trace, &proxy_trace, &merged] {
        let _ = std::fs::remove_file(f);
    }
    let _ = std::fs::remove_dir_all(&dir);
}
