//! Property tests for the distributed-fit wire codec (`gzk::dist::wire`):
//! every message round-trips through its JSON line — `RidgeStats` floats
//! **bit-exactly** — and malformed, hostile, or oversized frames are
//! rejected as error messages, never panics or silent truncation.

use gzk::data::DataSource;
use gzk::dist::{DataSpec, DistMsg, WireStats, DIST_PROTO, MAX_FRAME_BYTES};
use gzk::features::{FeatureSpec, KernelSpec, Method};
use gzk::krr::RidgeStats;
use gzk::linalg::Mat;
use gzk::server::listener::{read_line_bounded, LineRead};

use gzk::dist::wire::{
    assign_msg, done_msg, error_msg, job_msg, parse_msg, register_msg, stats_msg, ShardRange,
};

fn bound_spec(d: usize) -> gzk::features::BoundSpec {
    FeatureSpec::new(
        KernelSpec::Gaussian { bandwidth: 0.7 },
        Method::Gegenbauer { q: 6, s: 2 },
        64,
        0xDEAD_BEEF_CAFE_F00D,
    )
    .bind(d)
}

/// Floats chosen to break any formatter that is not shortest-round-trip:
/// a repeating binary fraction, negative zero, the smallest subnormal,
/// a near-overflow magnitude, and garden-variety negatives.
fn awkward_floats() -> Vec<f64> {
    vec![1.0 / 3.0, -0.0, 5e-324, 1.2345e300, -2.5e-17, f64::MAX, f64::MIN_POSITIVE, -1.0]
}

fn awkward_stats(f_dim: usize) -> WireStats {
    let vals = awkward_floats();
    let g = Mat::from_fn(f_dim, f_dim, |i, j| vals[(i * f_dim + j) % vals.len()]);
    let b: Vec<f64> = (0..f_dim).map(|i| vals[(i + 3) % vals.len()]).collect();
    WireStats {
        shard_id: 7,
        worker_id: 2,
        featurize_secs: 0.125,
        tid: 0,
        stats: RidgeStats { g, b, n: 8192, yy: vals[0] },
    }
}

#[test]
fn register_and_job_round_trip() {
    match parse_msg(&register_msg()).expect("register parses") {
        DistMsg::Register { proto } => assert_eq!(proto, DIST_PROTO),
        other => panic!("expected register, got {other:?}"),
    }
    // a peer speaking a different protocol version is rejected at parse
    let e = parse_msg(r#"{"dist":"register","proto":2}"#).unwrap_err();
    assert!(e.contains("protocol mismatch"), "{e}");

    // the job broadcast: the spec and the data descriptor both survive,
    // including a seed above 2^53 (carried as a decimal string — a
    // f64-backed JSON number would corrupt it)
    let spec = bound_spec(3);
    let data = DataSpec { name: "elevation".to_string(), rows: 4000, seed: u64::MAX - 12 };
    match parse_msg(&job_msg(5, &spec, &data, 0)).expect("job parses") {
        DistMsg::Job { worker_id, spec: wire_spec, data: wire_data, tid } => {
            assert_eq!(worker_id, 5);
            assert_eq!(wire_spec.to_json(), spec.to_json());
            assert_eq!(wire_data, data);
            // an untraced job carries no tid key at all — old peers see
            // byte-identical frames
            assert_eq!(tid, 0);
            assert!(!job_msg(5, &spec, &data, 0).contains("tid"));
        }
        other => panic!("expected job, got {other:?}"),
    }
    // a traced job round-trips a full-width u64 (decimal string on the
    // wire — a f64-backed JSON number would corrupt it)
    match parse_msg(&job_msg(5, &spec, &data, u64::MAX - 7)).expect("traced job parses") {
        DistMsg::Job { tid, .. } => assert_eq!(tid, u64::MAX - 7),
        other => panic!("expected job, got {other:?}"),
    }
    let e = parse_msg(r#"{"dist":"job","proto":1,"worker":0}"#).unwrap_err();
    assert!(e.contains("spec"), "{e}");
}

#[test]
fn assign_done_error_round_trip() {
    let t = ShardRange { shard_id: 3, lo: 24_576, hi: 32_768 };
    match parse_msg(&assign_msg(t, 0)).expect("assign parses") {
        DistMsg::Assign(r, tid) => {
            assert_eq!((r.shard_id, r.lo, r.hi), (t.shard_id, t.lo, t.hi));
            assert_eq!(tid, 0);
        }
        other => panic!("expected assign, got {other:?}"),
    }
    match parse_msg(&assign_msg(t, 0xF00D_F00D_F00D_F00D)).expect("traced assign parses") {
        DistMsg::Assign(_, tid) => assert_eq!(tid, 0xF00D_F00D_F00D_F00D),
        other => panic!("expected assign, got {other:?}"),
    }
    // an empty (or inverted) range can never be a valid task
    let e = parse_msg(r#"{"dist":"assign","shard_id":0,"lo":10,"hi":10}"#).unwrap_err();
    assert!(e.contains("empty range"), "{e}");

    assert!(matches!(parse_msg(&done_msg()), Ok(DistMsg::Done)));

    match parse_msg(&error_msg("disk \"gone\"", Some(4))).expect("error parses") {
        DistMsg::Error { error, shard_id } => {
            assert_eq!(error, "disk \"gone\"");
            assert_eq!(shard_id, Some(4));
        }
        other => panic!("expected error, got {other:?}"),
    }
    match parse_msg(&error_msg("no shard", None)).expect("error parses") {
        DistMsg::Error { shard_id, .. } => assert_eq!(shard_id, None),
        other => panic!("expected error, got {other:?}"),
    }
}

#[test]
fn stats_round_trip_is_bit_exact() {
    let original = awkward_stats(4);
    let line = stats_msg(&original).expect("finite stats encode");
    let ws = match parse_msg(&line).expect("stats parse") {
        DistMsg::Stats(ws) => *ws,
        other => panic!("expected stats, got {other:?}"),
    };
    assert_eq!(ws.shard_id, original.shard_id);
    assert_eq!(ws.worker_id, original.worker_id);
    assert_eq!(ws.tid, 0);
    assert!(!line.contains("tid"), "untraced stats must not grow a tid key");
    assert_eq!(ws.featurize_secs.to_bits(), original.featurize_secs.to_bits());
    assert_eq!(ws.stats.n, original.stats.n);
    assert_eq!(ws.stats.yy.to_bits(), original.stats.yy.to_bits());
    // bit-for-bit, not approximately: the leader's merge reproduces the
    // in-process fit only if the wire is an identity on floats
    for (a, b) in ws.stats.b.iter().zip(&original.stats.b) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    for (a, b) in ws.stats.g.data().iter().zip(original.stats.g.data()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }

    // a traced reply echoes the run's trace ID at full u64 width
    let mut traced = awkward_stats(2);
    traced.tid = u64::MAX - 1;
    let traced_line = stats_msg(&traced).expect("traced stats encode");
    match parse_msg(&traced_line).expect("traced stats parse") {
        DistMsg::Stats(ws) => assert_eq!(ws.tid, u64::MAX - 1),
        other => panic!("expected stats, got {other:?}"),
    }
}

#[test]
fn encoder_and_parser_both_refuse_non_finite_stats() {
    // encode side: a NaN statistic degrades to an error, never a panic in
    // the shortest-round-trip formatter
    let mut bad = awkward_stats(2);
    bad.stats.yy = f64::NAN;
    let e = stats_msg(&bad).unwrap_err();
    assert!(e.contains("non-finite"), "{e}");

    // parse side: "1e999" is valid JSON that parses to +inf — a hostile
    // worker must not be able to poison the merge with it
    let line = concat!(
        r#"{"dist":"stats","shard_id":0,"worker":0,"featurize_secs":0.1,"n":4,"yy":1e999,"#,
        r#""b":[1.0,2.0],"g":{"rows":2,"cols":2,"data":[1.0,0.0,0.0,1.0]}}"#
    );
    let e = parse_msg(line).unwrap_err();
    assert!(e.contains("non-finite"), "{e}");
}

#[test]
fn parser_rejects_hostile_shapes_and_garbage() {
    // a non-square Gram, and a Gram/b dimension mismatch
    let cases = [
        concat!(
            r#"{"dist":"stats","shard_id":0,"worker":0,"featurize_secs":0.1,"n":4,"yy":1.0,"#,
            r#""b":[1.0,2.0],"g":{"rows":2,"cols":3,"data":[0,0,0,0,0,0]}}"#
        ),
        concat!(
            r#"{"dist":"stats","shard_id":0,"worker":0,"featurize_secs":0.1,"n":4,"yy":1.0,"#,
            r#""b":[1.0,2.0,3.0],"g":{"rows":2,"cols":2,"data":[0,0,0,0]}}"#
        ),
    ];
    for line in cases {
        let e = parse_msg(line).unwrap_err();
        assert!(e.contains("inconsistent dimensions"), "{e}");
    }
    // garbage lines degrade to error messages, never panics
    for line in [
        "",
        "not json",
        "{}",
        r#"{"dist":42}"#,
        r#"{"dist":"warp"}"#,
        r#"{"dist":"assign","shard_id":0,"lo":0}"#,
        r#"{"dist":"stats","shard_id":0}"#,
        r#"{"dist":"register"}"#,
        r#"{"dist":"error"}"#,
    ] {
        assert!(parse_msg(line).is_err(), "accepted garbage: {line:?}");
    }
}

#[test]
fn bounded_reader_rejects_oversized_frames() {
    use std::io::Cursor;
    // a well-formed line under the cap reads back exactly
    let mut buf = Vec::new();
    let mut ok = Cursor::new(b"{\"dist\":\"done\"}\nrest".to_vec());
    assert_eq!(read_line_bounded(&mut ok, &mut buf, 64, None), LineRead::Line);
    assert_eq!(buf, b"{\"dist\":\"done\"}");

    // a peer streaming bytes with no newline hits the cap, not the heap
    let mut hostile = Cursor::new(vec![b'x'; 1024]);
    assert_eq!(read_line_bounded(&mut hostile, &mut buf, 64, None), LineRead::Overlong);

    // EOF with a non-empty buffer still yields the final line; EOF on an
    // empty stream is a clean end
    let mut tail = Cursor::new(b"{\"dist\":\"done\"}".to_vec());
    assert_eq!(read_line_bounded(&mut tail, &mut buf, 64, None), LineRead::Line);
    let mut empty = Cursor::new(Vec::new());
    assert_eq!(read_line_bounded(&mut empty, &mut buf, 64, None), LineRead::Eof);

    // the dist cap really is wide enough for a Gram frame the serving cap
    // would reject (the reason the two limits are distinct constants)
    assert!(MAX_FRAME_BYTES > gzk::server::listener::MAX_LINE_BYTES);
}

#[test]
fn data_spec_open_validates_its_descriptor() {
    // synthetic descriptors resolve by name with exactly `rows` rows
    let spec = DataSpec { name: "elevation".to_string(), rows: 100, seed: 3 };
    let src = spec.open().expect("elevation opens");
    assert_eq!(src.len(), 100);

    // an unknown generator and a missing file both fail with a message
    assert!(DataSpec { name: "no-such-set".to_string(), rows: 10, seed: 3 }.open().is_err());
    assert!(DataSpec { name: "file:/nonexistent/gzk.csv".to_string(), rows: 10, seed: 3 }
        .open()
        .is_err());
}
