//! End-to-end CLI tests: the fit → persist → reload → serve lifecycle
//! through the actual `gzk` binary, on synthetic data at test-friendly
//! sizes. These are the acceptance checks that the serve path loads from a
//! `ModelStore` (no refit) and that usage mistakes exit cleanly.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_gzk"))
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("gzk-cli-e2e-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn run_ok(args: &[&str]) -> String {
    let out = bin().args(args).output().expect("spawn gzk");
    assert!(
        out.status.success(),
        "gzk {args:?} failed\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn fit_then_predict_ridge_roundtrip_on_disk() {
    let dir = fresh_dir("ridge");
    let dir_s = dir.to_str().unwrap();
    let stdout = run_ok(&[
        "fit", "--model", "ridge", "--out", dir_s, "--n", "400", "--m", "64", "--workers", "2",
    ]);
    assert!(stdout.contains("one-round fit"), "{stdout}");
    assert!(stdout.contains("saved model"), "{stdout}");
    assert!(dir.join("models.json").exists());
    assert!(dir.join("ridge.model.json").exists());

    // a separate process reloads the artifact and serves it
    let stdout = run_ok(&["predict", "--model-dir", dir_s, "--requests", "50"]);
    assert!(stdout.contains("no refit"), "{stdout}");
    assert!(stdout.contains("served 50 requests"), "{stdout}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fit_then_predict_kmeans_and_kpca() {
    let dir = fresh_dir("multi");
    let dir_s = dir.to_str().unwrap();
    run_ok(&[
        "fit", "--model", "kmeans", "--out", dir_s, "--n", "300", "--d", "4", "--k", "2",
        "--m", "32",
    ]);
    run_ok(&["fit", "--model", "kpca", "--out", dir_s, "--n", "300", "--rank", "2", "--m", "32"]);
    // two models in the store: predict must require --name
    let out = bin().args(["predict", "--model-dir", dir_s]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stderr).contains("--name"));
    let stdout = run_ok(&["predict", "--model-dir", dir_s, "--name", "kmeans", "--requests", "20"]);
    assert!(stdout.contains("kind kmeans"), "{stdout}");
    let stdout = run_ok(&["predict", "--model-dir", dir_s, "--name", "kpca", "--requests", "20"]);
    assert!(stdout.contains("output dim 2"), "{stdout}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_trains_once_then_loads_the_stored_artifact() {
    let dir = fresh_dir("serve");
    let dir_s = dir.to_str().unwrap();
    // first run: trains via the one-round protocol over the chunked
    // source, persists, serves the reloaded artifact
    let stdout = run_ok(&[
        "serve", "--n", "600", "--m", "64", "--requests", "100", "--model-dir", dir_s,
    ]);
    assert!(stdout.contains("trained on"), "{stdout}");
    assert!(stdout.contains("saved model"), "{stdout}");
    assert!(stdout.contains("served 100 requests"), "{stdout}");
    assert!(stdout.contains("held-out MSE"), "{stdout}");
    // second run: same store — must load, never refit (training flags are
    // dropped: serve rejects them when the stored model is used). The
    // artifact records the training dataset + row count, so the stored
    // path rebuilds the SAME generator's held-out rows and still reports
    // an honest MSE.
    let stdout = run_ok(&["serve", "--requests", "100", "--model-dir", dir_s]);
    assert!(stdout.contains("no refit"), "{stdout}");
    assert!(!stdout.contains("trained on"), "refit happened: {stdout}");
    assert!(stdout.contains("served 100 requests"), "{stdout}");
    assert!(stdout.contains("held-out elevation rows"), "{stdout}");
    assert!(stdout.contains("held-out MSE"), "{stdout}");
    // training flags alongside a stored model are a usage error, not a
    // silent no-op
    let out = bin()
        .args(["serve", "--m", "128", "--requests", "10", "--model-dir", dir_s])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stderr).contains("--m"), "stderr should name the flag");
    // ...including the new data-pipeline flags
    let out = bin()
        .args(["serve", "--chunk-rows", "64", "--requests", "10", "--model-dir", dir_s])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("--chunk-rows"),
        "stderr should name the flag"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_refuses_a_kmeans_model_by_name() {
    // serve scores regression; a stored k-means model must be redirected
    // to `gzk predict`, not silently scored
    let dir = fresh_dir("serve-kind");
    let dir_s = dir.to_str().unwrap();
    run_ok(&[
        "fit", "--model", "kmeans", "--out", dir_s, "--n", "200", "--d", "3", "--k", "2",
        "--m", "32", "--name", "clusters",
    ]);
    let out = bin()
        .args(["serve", "--requests", "10", "--model-dir", dir_s, "--name", "clusters"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stderr).contains("predict"), "{out:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fit_and_predict_from_a_csv_file_source() {
    // the out-of-core file path end to end: write a CSV, fit ridge over it
    // in 64-row chunks, reload the artifact in a separate process and serve
    let dir = fresh_dir("csv");
    let dir_s = dir.to_str().unwrap();
    let csv = std::env::temp_dir().join(format!("gzk-cli-e2e-{}.csv", std::process::id()));
    let mut text = String::from("# y = x0 + 2*x1 on a grid\n");
    for i in 0..300 {
        let (a, b) = ((i % 17) as f64 / 17.0, (i % 23) as f64 / 23.0);
        text.push_str(&format!("{a},{b},{}\n", a + 2.0 * b));
    }
    std::fs::write(&csv, text).unwrap();
    let stdout = run_ok(&[
        "fit", "--model", "ridge", "--out", dir_s, "--data", csv.to_str().unwrap(),
        "--chunk-rows", "64", "--m", "64", "--workers", "2",
    ]);
    assert!(stdout.contains("one-round fit"), "{stdout}");
    assert!(stdout.contains("test MSE"), "{stdout}");
    assert!(stdout.contains("saved model"), "{stdout}");
    // the artifact records where the data came from
    let artifact = std::fs::read_to_string(dir.join("ridge.model.json")).unwrap();
    assert!(artifact.contains(r#""dataset":"file:"#), "{artifact}");
    // a separate process reloads and serves it
    let stdout = run_ok(&["predict", "--model-dir", dir_s, "--requests", "20"]);
    assert!(stdout.contains("no refit"), "{stdout}");
    assert!(stdout.contains("served 20 requests"), "{stdout}");
    // serve cannot regenerate file data: it must error, naming the source
    let out = bin().args(["serve", "--requests", "10", "--model-dir", dir_s]).output().unwrap();
    assert_eq!(out.status.code(), Some(1), "{out:?}");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("file:") && stderr.contains("predict"), "{stderr}");
    // conflicting / malformed data flags are clean usage errors
    let out = bin()
        .args(["fit", "--out", dir_s, "--data", csv.to_str().unwrap(), "--dataset", "co2"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(2));
    let out = bin()
        .args(["fit", "--out", dir_s, "--data", "/no/such/file.csv"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1), "missing file is a runtime error");
    let _ = std::fs::remove_file(&csv);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fit_streams_any_synthetic_dataset() {
    // --dataset selects the lazy generator; climate is the d=4 source
    let dir = fresh_dir("dataset");
    let dir_s = dir.to_str().unwrap();
    let stdout = run_ok(&[
        "fit", "--model", "ridge", "--out", dir_s, "--dataset", "climate", "--n", "500",
        "--m", "48", "--chunk-rows", "128",
    ]);
    assert!(stdout.contains("test MSE"), "{stdout}");
    let artifact = std::fs::read_to_string(dir.join("ridge.model.json")).unwrap();
    assert!(artifact.contains(r#""dataset":"climate""#), "{artifact}");
    assert!(artifact.contains(r#""rows":500"#), "{artifact}");
    // unknown dataset names are usage errors listing the registry
    let out = bin()
        .args(["fit", "--out", dir_s, "--dataset", "no-such-set"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stderr).contains("elevation"), "{out:?}");
    // --d with a named dataset would be silently ignored (the source fixes
    // its own dimension) — rejected instead
    let out = bin()
        .args(["fit", "--out", dir_s, "--dataset", "climate", "--d", "16"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stderr).contains("--d"), "{out:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn malformed_flag_value_is_a_clean_usage_error() {
    // the cli satellite: exit(2) + the flag-naming message, no backtrace
    let out = bin().args(["serve", "--m", "10k24"]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("flag --m"), "{stderr}");
    assert!(stderr.contains("10k24"), "{stderr}");
    assert!(!stderr.contains("panicked"), "panic leaked to the user: {stderr}");
}

#[test]
fn threads_flag_is_global_and_recorded_in_run_metadata() {
    let dir = fresh_dir("threads");
    let dir_s = dir.to_str().unwrap();
    let stdout = run_ok(&[
        "fit", "--model", "ridge", "--out", dir_s, "--n", "300", "--m", "32", "--workers", "2",
        "--threads", "2",
    ]);
    assert!(stdout.contains("saved model"), "{stdout}");
    // the artifact documents the pool width and training data that
    // produced it
    let artifact = std::fs::read_to_string(dir.join("ridge.model.json")).unwrap();
    assert!(artifact.contains(r#""run":{"threads":2,"dataset":"elevation","rows":300"#), "{artifact}");
    // predict accepts the flag too: it configures serving, not training
    let stdout =
        run_ok(&["predict", "--model-dir", dir_s, "--requests", "10", "--threads", "1"]);
    assert!(stdout.contains("serving pool: 1 threads"), "{stdout}");
    assert!(stdout.contains("served 10 requests"), "{stdout}");
    // nonsense widths are a clean usage error naming the flag
    let out = bin().args(["serve", "--threads", "0"]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stderr).contains("--threads"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fit_requires_an_output_dir() {
    let out = bin().args(["fit", "--model", "ridge"]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stderr).contains("--out"));
}
