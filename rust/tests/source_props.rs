//! Chunk-invariance property tests for the out-of-core data pipeline:
//! for EVERY registry method, a chunked fit (ridge / k-means / KPCA) over
//! a `DataSource` is **bit-identical** across chunk sizes {1, 17, 64, n}
//! — and, where a materialized one-shot fit exists (ridge, KPCA), equal
//! to that fit as well. This is the contract that lets `--chunk-rows`
//! bound working memory without changing a single bit of any model.

use gzk::data::{pipeline, DataSource, MatSource, SyntheticSource};
use gzk::exec::Pool;
use gzk::features::{FeatureSpec, Featurizer, KernelSpec, Method};
use gzk::kpca::KernelPca;
use gzk::krr::FeatureRidge;
use gzk::model::{from_artifact, Model, RidgeModel};

const CHUNKS: [usize; 4] = [1, 17, 64, usize::MAX]; // MAX -> clamped to n

fn spec_for(method: Method, m: usize, seed: u64) -> FeatureSpec {
    FeatureSpec::new(KernelSpec::Gaussian { bandwidth: 1.1 }, method.tuned(6, 2), m, seed)
}

#[test]
fn ridge_chunked_fit_is_bit_identical_to_one_shot_for_every_method() {
    let n = 64;
    let src = SyntheticSource::elevation(n, 41);
    let (x, y) = src.read_range(0, n).unwrap();
    for method in Method::registry() {
        let spec = spec_for(method, 48, 7);
        // data-dependent Nystrom builds from the same gathered sample in
        // both paths, so it participates in the invariance too
        let feat = spec.build_with_data(&x);
        let z = feat.featurize(&x);
        let reference = FeatureRidge::fit(&z, &y, 0.01);
        for chunk in CHUNKS {
            let chunk = chunk.min(n);
            let (stats, info) =
                pipeline::ridge_stats(feat.as_ref(), &src, chunk, &Pool::global()).unwrap();
            let model = stats.solve(0.01);
            assert_eq!(
                model.weights,
                reference.weights,
                "{}: chunk {chunk} drifted from the one-shot fit",
                feat.name()
            );
            assert_eq!(stats.n, n);
            // the memory claim: scratch is chunk x F, not n x F
            assert_eq!(info.peak_z_bytes, chunk * feat.dim() * 8, "{}", feat.name());
        }
    }
}

#[test]
fn kpca_chunked_fit_is_bit_identical_to_one_shot_for_every_method() {
    let n = 64;
    let src = SyntheticSource::protein(n, 42);
    let (x, _) = src.read_range(0, n).unwrap();
    for method in Method::registry() {
        let spec = spec_for(method, 32, 9);
        let feat = spec.build_with_data(&x);
        let z = feat.featurize(&x);
        let reference = KernelPca::fit(&z, 3);
        for chunk in CHUNKS {
            let chunk = chunk.min(n);
            let (pca, _) =
                pipeline::kpca_chunked(feat.as_ref(), &src, 3, chunk, &Pool::global()).unwrap();
            assert_eq!(pca.mean(), reference.mean(), "{}: chunk {chunk}", feat.name());
            assert_eq!(
                pca.components(),
                reference.components(),
                "{}: chunk {chunk}",
                feat.name()
            );
            assert_eq!(
                pca.eigenvalues,
                reference.eigenvalues,
                "{}: chunk {chunk}",
                feat.name()
            );
        }
    }
}

#[test]
fn kmeans_chunked_fit_is_chunk_invariant_for_every_method() {
    // k-means' one-shot algorithm is Lloyd (inherently multi-pass over
    // resident features), so the streamed fit's contract is invariance:
    // any chunking reproduces the whole-source-in-one-chunk fit exactly
    let n = 64;
    let src = SyntheticSource::by_name("abalone", n, 43).unwrap();
    let (x, _) = src.read_range(0, n).unwrap();
    for method in Method::registry() {
        let spec = spec_for(method, 32, 11);
        let feat = spec.build_with_data(&x);
        let (reference, _) =
            pipeline::kmeans_chunked(feat.as_ref(), &src, 3, n, 13, &Pool::global()).unwrap();
        for chunk in CHUNKS {
            let chunk = chunk.min(n);
            let (fit, _) =
                pipeline::kmeans_chunked(feat.as_ref(), &src, 3, chunk, 13, &Pool::global())
                    .unwrap();
            assert_eq!(
                fit.centroids,
                reference.centroids,
                "{}: chunk {chunk} drifted",
                feat.name()
            );
            assert_eq!(fit.objective, reference.objective, "{}: chunk {chunk}", feat.name());
        }
        assert!(reference.objective.is_finite() && reference.objective >= 0.0);
    }
}

#[test]
fn model_fit_source_artifacts_are_chunk_invariant() {
    // the full deployable path: fit_source -> artifact -> reload ->
    // predict is the same model at every chunk size, for a file-free
    // in-memory source and the lazy generator alike
    let n = 60;
    let src = SyntheticSource::climate(n, 44);
    let (x, y) = src.read_range(0, n).unwrap();
    let mat = MatSource::new(&x, &y);
    let spec = spec_for(Method::Gegenbauer { q: 6, s: 2 }, 40, 17).bind(4);
    let reference = RidgeModel::fit_source(spec.clone(), &src, 1e-3, n).unwrap();
    let probe = x.row_block(0, 8);
    for chunk in [1usize, 17, 64] {
        let a = RidgeModel::fit_source(spec.clone(), &src, 1e-3, chunk).unwrap();
        let b = RidgeModel::fit_source(spec.clone(), &mat, 1e-3, chunk).unwrap();
        assert_eq!(a.predict_vec(&probe), reference.predict_vec(&probe), "chunk {chunk}");
        assert_eq!(b.predict_vec(&probe), reference.predict_vec(&probe), "mat chunk {chunk}");
        let reloaded = from_artifact(&a.to_artifact()).unwrap();
        assert_eq!(reloaded.predict(&probe), Model::predict(&a, &probe), "chunk {chunk}");
    }
}

#[test]
fn nystrom_fit_source_matches_in_memory_fit() {
    // the data-dependent baseline: landmarks gathered by random access
    // from a lazy source equal the landmarks of the materialized fit
    use gzk::features::NystromFeatures;
    use gzk::kernels::Kernel;
    let n = 50;
    let src = SyntheticSource::elevation(n, 45);
    let (x, _) = src.read_range(0, n).unwrap();
    let from_mat = NystromFeatures::fit(Kernel::Gaussian { bandwidth: 1.0 }, &x, 12, 1e-4, 3);
    let from_src =
        NystromFeatures::fit_source(Kernel::Gaussian { bandwidth: 1.0 }, &src, 12, 1e-4, 3)
            .unwrap();
    assert_eq!(from_mat.landmarks(), from_src.landmarks());
    assert_eq!(from_mat.featurize(&x), from_src.featurize(&x));
}
