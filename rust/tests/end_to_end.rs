//! Cross-module integration tests: featurizers -> KRR/k-means -> spectral
//! validators on the synthetic datasets, at test-friendly sizes.

use gzk::data;
use gzk::features::{FeatureSpec, Featurizer, GegenbauerFeatures, KernelSpec, Method, RadialTable};
use gzk::kernels::Kernel;
use gzk::kmeans::{greedy_accuracy, kmeans};
use gzk::krr::{mse, ExactKrr, FeatureRidge};
use gzk::spectral::spectral_epsilon;

#[test]
fn all_registered_methods_learn_elevation() {
    // every featurizer in the registry must beat the predict-the-mean
    // baseline on the S^2 elevation task (Table-2 smoke at small n)
    let ds = data::elevation(1200, 3);
    let (x_tr, y_tr, x_te, y_te) = data::split(&ds.x, &ds.y, 0.2, 3);
    let ybar = y_tr.iter().sum::<f64>() / y_tr.len() as f64;
    let base = y_te.iter().map(|v| (v - ybar) * (v - ybar)).sum::<f64>() / y_te.len() as f64;

    for (i, method) in Method::registry().into_iter().enumerate() {
        let spec = FeatureSpec::new(
            KernelSpec::Gaussian { bandwidth: 1.0 },
            method.tuned(10, 2),
            512,
            1 + i as u64,
        );
        let feat = spec.build_with_data(&x_tr);
        let z_tr = feat.featurize(&x_tr);
        let z_te = feat.featurize(&x_te);
        let model = FeatureRidge::fit(&z_tr, &y_tr, 1e-2);
        let err = mse(&model.predict(&z_te), &y_te);
        assert!(err < 0.8 * base, "{}: mse {err} vs baseline {base}", feat.name());
    }
}

#[test]
fn gegenbauer_tracks_exact_krr_on_co2() {
    let ds = data::co2(700, 5);
    let (x_tr, y_tr, x_te, y_te) = data::split(&ds.x, &ds.y, 0.2, 5);
    let lam = 1e-2;
    let exact = ExactKrr::fit(Kernel::Gaussian { bandwidth: 1.0 }, x_tr.clone(), &y_tr, lam);
    let feat = GegenbauerFeatures::new(RadialTable::gaussian(4, 10, 3), 1024, 7);
    let z_tr = feat.featurize(&x_tr);
    let z_te = feat.featurize(&x_te);
    let model = FeatureRidge::fit(&z_tr, &y_tr, lam);
    let mse_feat = mse(&model.predict(&z_te), &y_te);
    let mse_exact = mse(&exact.predict(&x_te), &y_te);
    assert!(
        mse_feat < 1.5 * mse_exact + 5e-3,
        "features {mse_feat} vs exact {mse_exact}"
    );
}

#[test]
fn kmeans_recovers_clusters_through_features() {
    let spec = gzk::data::ClusteringSpec { name: "itest", n: 900, d: 8, k: 3 };
    let ds = data::clustering_dataset(spec, 9);
    let feat = GegenbauerFeatures::new(RadialTable::gaussian(8, 8, 2), 256, 10);
    let z = feat.featurize(&ds.x);
    let res = kmeans(&z, 3, 50, 11);
    let acc = greedy_accuracy(&res.assignments, &ds.labels, 3);
    // unit-norm mixtures overlap by construction; well above chance (1/3)
    // is what the feature map must preserve
    assert!(acc > 0.70, "accuracy {acc}");
}

#[test]
fn spectral_certificate_on_protein_subset() {
    let ds = data::protein(80, 13);
    let mut x = ds.x.clone();
    // protein is standardized; scale down so the Gaussian kernel has mass
    x.scale(0.35);
    let k = Kernel::Gaussian { bandwidth: 1.0 }.gram(&x);
    let feat = GegenbauerFeatures::new(RadialTable::gaussian(9, 10, 3), 4096, 14);
    let z = feat.featurize(&x);
    let eps = spectral_epsilon(&k, &z.matmul_nt(&z), 0.5);
    assert!(eps < 0.8, "eps {eps}");
}

#[test]
fn ntk_features_track_exact_ntk_krr() {
    // the paper's NTK claim end-to-end: random Gegenbauer features for the
    // depth-2 ReLU NTK approximate exact NTK kernel regression on S^3 data
    let mut rng = gzk::rng::Rng::new(40);
    let n = 150;
    let d = 4;
    let mut x = gzk::linalg::Mat::zeros(n, d);
    for i in 0..n {
        rng.sphere(x.row_mut(i));
    }
    let y: Vec<f64> =
        (0..n).map(|i| (3.0 * x[(i, 0)]).sin() + x[(i, 1)] * x[(i, 2)] + 0.02 * rng.normal()).collect();
    let lam = 1e-2;
    let exact = ExactKrr::fit(Kernel::Ntk { depth: 2 }, x.clone(), &y, lam);
    let feat = GegenbauerFeatures::new(gzk::features::RadialTable::ntk(d, 24, 2), 4096, 41);
    let z = feat.featurize(&x);
    let model = FeatureRidge::fit(&z, &y, lam);
    let mut xt = gzk::linalg::Mat::zeros(40, d);
    for i in 0..40 {
        rng.sphere(xt.row_mut(i));
    }
    let pe = exact.predict(&xt);
    let pa = model.predict(&feat.featurize(&xt));
    let diff = mse(&pa, &pe);
    assert!(diff < 1e-2, "feature-NTK vs exact-NTK prediction gap {diff}");
}

#[test]
fn parallel_featurize_in_krr_pipeline() {
    // featurize_par must be a drop-in replacement on a real workload
    let ds = data::elevation(2000, 21);
    let feat = GegenbauerFeatures::new(RadialTable::gaussian(3, 10, 2), 256, 22);
    let z_seq = feat.featurize(&ds.x);
    let z_par = feat.featurize_par(&ds.x, &gzk::exec::Pool::new(4));
    assert_eq!(z_seq, z_par);
}

#[test]
fn synthetic_datasets_have_documented_sizes() {
    // DESIGN.md promises the paper's (n, d) geometry; spot-check generators
    let e = data::elevation(100, 1);
    assert_eq!(e.x.cols(), 3);
    let c = data::co2(100, 1);
    assert_eq!(c.x.cols(), 4);
    let p = data::protein(100, 1);
    assert_eq!(p.x.cols(), 9);
    assert_eq!(data::CLUSTERING_SPECS.len(), 6);
    let total: usize = data::CLUSTERING_SPECS.iter().map(|s| s.n).sum();
    assert_eq!(total, 4_177 + 7_494 + 8_124 + 19_020 + 43_500 + 67_557);
}
