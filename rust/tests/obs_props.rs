//! Observability-layer properties: the registry under concurrent
//! writers, the 1-2-5 histogram ladder at its edges, and — through the
//! actual `gzk` binary — the "instrumentation is read-only" contract:
//! a fit run with `--trace-out` produces a byte-identical artifact AND
//! a valid Chrome trace, and CLI errors land in `--log-file` as
//! parseable newline-JSON events.

use gzk::obs::registry;
use gzk::runtime::Json;
use std::path::PathBuf;
use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_gzk"))
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("gzk-obs-props-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn registry_snapshot_is_consistent_under_concurrent_writers() {
    // 8 threads hammer one counter, one gauge and one histogram; every
    // update must land and the snapshot must stay one valid JSON document
    const THREADS: usize = 8;
    const PER_THREAD: u64 = 5_000;
    let c = registry::counter("obsprops.hits");
    let g = registry::gauge("obsprops.level");
    let h = registry::hist("obsprops.lat_s");
    std::thread::scope(|scope| {
        for t in 0..THREADS {
            let (c, g, h) = (c.clone(), g.clone(), h.clone());
            scope.spawn(move || {
                for i in 0..PER_THREAD {
                    c.inc();
                    g.add(if t % 2 == 0 { 1 } else { -1 });
                    h.record(1e-6 * (i % 100 + 1) as f64);
                }
            });
        }
        // snapshots taken mid-flight must always parse
        for _ in 0..20 {
            let snap = registry::snapshot_json();
            Json::parse(&snap).unwrap_or_else(|e| panic!("mid-flight snapshot invalid: {e}"));
        }
    });
    assert_eq!(c.get(), (THREADS as u64) * PER_THREAD);
    assert_eq!(g.get(), 0, "paired +1/-1 updates must cancel");
    assert_eq!(h.total(), (THREADS as u64) * PER_THREAD);
    let snap = Json::parse(&registry::snapshot_json()).expect("final snapshot parses");
    let hits = snap
        .get("counters")
        .and_then(|c| c.get("obsprops.hits"))
        .and_then(Json::as_f64)
        .expect("counter in snapshot");
    assert_eq!(hits as u64, (THREADS as u64) * PER_THREAD);
}

#[test]
fn histogram_ladder_edges_round_trip() {
    let h = registry::hist("obsprops.edges");
    // exactly on the lowest bound: first cell, and its quantile reports
    // that bound
    h.record(1e-6);
    assert_eq!(h.counts()[0], 1);
    assert_eq!(h.quantile(0.5), registry::LADDER_BOUNDS[0]);
    // exactly on the highest bound: last real cell, not overflow
    h.record(50.0);
    assert_eq!(h.counts()[registry::LADDER_CELLS - 2], 1);
    // past the top: the overflow cell, reported as 2x the last bound
    h.record(100.0);
    assert_eq!(h.counts()[registry::LADDER_CELLS - 1], 1);
    assert_eq!(h.quantile(1.0), 2.0 * registry::LADDER_BOUNDS[registry::LADDER_BOUNDS.len() - 1]);
    // below the bottom still lands in the first cell
    h.record(1e-9);
    assert_eq!(h.counts()[0], 2);
    assert_eq!(h.total(), 4);
    // and the bucket function agrees with where the records landed
    assert_eq!(registry::ladder_bucket(1e-6), 0);
    assert_eq!(registry::ladder_bucket(50.0), registry::LADDER_CELLS - 2);
    assert_eq!(registry::ladder_bucket(100.0), registry::LADDER_CELLS - 1);
}

#[test]
fn traced_fit_is_bit_identical_and_the_trace_parses() {
    // the acceptance check for "observability is read-only": the same fit
    // with and without --trace-out must produce byte-identical artifacts,
    // and the trace must be a valid Chrome trace-event document covering
    // the fit stages
    let plain = fresh_dir("plain");
    let traced = fresh_dir("traced");
    let trace_path = std::env::temp_dir()
        .join(format!("gzk-obs-props-{}-trace.json", std::process::id()));
    let fit = |dir: &PathBuf, extra: &[&str]| {
        let mut args = vec![
            "fit", "--model", "ridge", "--out", dir.to_str().unwrap(), "--n", "400", "--m",
            "64", "--workers", "2", "--chunk-rows", "128", "--seed", "7",
        ];
        args.extend_from_slice(extra);
        let out = bin().args(&args).output().expect("spawn gzk");
        assert!(
            out.status.success(),
            "gzk {args:?} failed\nstderr: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        String::from_utf8_lossy(&out.stdout).into_owned()
    };
    fit(&plain, &[]);
    let stdout = fit(&traced, &["--trace-out", trace_path.to_str().unwrap()]);
    assert!(stdout.contains("wrote trace"), "{stdout}");

    let a = std::fs::read(plain.join("ridge.model.json")).expect("plain artifact");
    let b = std::fs::read(traced.join("ridge.model.json")).expect("traced artifact");
    assert_eq!(a, b, "tracing perturbed the fit artifact");

    let text = std::fs::read_to_string(&trace_path).expect("trace file");
    let doc = Json::parse(&text).expect("trace is valid JSON");
    let events = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    assert!(!events.is_empty(), "trace has no spans");
    let names: Vec<String> = events
        .iter()
        .filter_map(|e| e.get("name").and_then(Json::as_str).map(str::to_string))
        .collect();
    for expected in ["featurize", "absorb", "solve", "scatter", "merge", "chunk.read"] {
        assert!(names.iter().any(|n| n == expected), "no {expected:?} span in {names:?}");
    }
    for e in events {
        assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"));
        assert!(e.get("ts").and_then(Json::as_f64).is_some());
        assert!(e.get("dur").and_then(Json::as_f64).is_some());
        assert!(e.get("cat").and_then(Json::as_str).is_some());
    }
    let _ = std::fs::remove_file(&trace_path);
    let _ = std::fs::remove_dir_all(&plain);
    let _ = std::fs::remove_dir_all(&traced);
}

#[test]
fn cli_errors_land_in_the_log_file_as_json_events() {
    let log_path =
        std::env::temp_dir().join(format!("gzk-obs-props-{}-events.log", std::process::id()));
    let _ = std::fs::remove_file(&log_path);
    // a malformed flag after --log-file took effect: the usage error is a
    // structured event in the file, not bare stderr text (--out must be
    // valid — fit checks it before parsing the featurizer flag group)
    let out_dir = fresh_dir("logfile");
    let out = bin()
        .args([
            "fit",
            "--log-file",
            log_path.to_str().unwrap(),
            "--out",
            out_dir.to_str().unwrap(),
            "--m",
            "10k24",
        ])
        .output()
        .expect("spawn gzk");
    assert_eq!(out.status.code(), Some(2));
    let text = std::fs::read_to_string(&log_path).expect("log file written");
    let line = text.lines().next().expect("at least one event");
    let ev = Json::parse(line).expect("event line is valid JSON");
    assert_eq!(ev.get("level").and_then(Json::as_str), Some("error"));
    let msg = ev.get("msg").and_then(Json::as_str).expect("msg field");
    assert!(msg.contains("argument error") && msg.contains("--m"), "{msg}");
    assert!(ev.get("ts").and_then(Json::as_f64).is_some());
    let _ = std::fs::remove_file(&log_path);
    let _ = std::fs::remove_dir_all(&out_dir);

    // a bogus GZK_LOG value is a usage error naming the env var
    let out = bin().args(["fit", "--n", "50"]).env("GZK_LOG", "loud").output().expect("spawn");
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("GZK_LOG"), "{stderr}");

    // --log-level filters: at error level an info-emitting run stays quiet
    let out = bin()
        .args(["fit", "--log-level", "bogus"])
        .output()
        .expect("spawn");
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stderr).contains("--log-level"));
}
