//! Batcher-under-concurrency properties: N client threads × M requests
//! through ONE `PredictionService`, asserting that no reply is lost,
//! duplicated or cross-wired, that `max_batch` is respected, and that the
//! metrics totals are consistent with what the clients observed. This is
//! the correctness foundation the network serving layer
//! (`tests/server_e2e.rs`) builds on.

use gzk::coordinator::PredictionService;
use gzk::features::{FeatureSpec, Featurizer as _, KernelSpec, Method};
use gzk::krr::FeatureRidge;
use gzk::linalg::Mat;
use gzk::rng::Rng;
use std::time::Duration;

const N_CLIENTS: usize = 8;
const N_REQUESTS: usize = 40;
const MAX_BATCH: usize = 4;

fn trained(n: usize) -> (gzk::features::BoundSpec, FeatureRidge, Mat, Vec<f64>) {
    let spec = FeatureSpec::new(
        KernelSpec::Gaussian { bandwidth: 1.0 },
        Method::Gegenbauer { q: 6, s: 2 },
        64,
        33,
    )
    .bind(3);
    let mut rng = Rng::new(44);
    let x = Mat::from_fn(n, 3, |_, _| rng.normal() * 0.5);
    let y: Vec<f64> = (0..n).map(|i| x[(i, 0)] - 0.5 * x[(i, 2)]).collect();
    let z = spec.build().featurize(&x);
    let model = FeatureRidge::fit(&z, &y, 1e-3);
    (spec, model, x, y)
}

#[test]
fn concurrent_clients_lose_nothing_and_metrics_add_up() {
    let (spec, model, x, _) = trained(N_CLIENTS * N_REQUESTS);
    let z = spec.build().featurize(&x);
    let expect = model.predict(&z);
    let svc = PredictionService::start(spec, model, MAX_BATCH, Duration::from_micros(200))
        .expect("start service");

    // Every client owns a disjoint row range and checks each reply
    // against the direct (unbatched) prediction for EXACTLY that row —
    // a lost reply hangs recv (caught by the harness), a duplicated or
    // cross-wired one shows up as a value mismatch.
    std::thread::scope(|scope| {
        for t in 0..N_CLIENTS {
            let client = svc.client();
            let x = &x;
            let expect = &expect;
            scope.spawn(move || {
                for r in 0..N_REQUESTS {
                    let i = t * N_REQUESTS + r;
                    let got = client.predict(x.row(i)).expect("served");
                    assert_eq!(
                        got.to_bits(),
                        expect[i].to_bits(),
                        "client {t} request {r}: reply for the wrong row"
                    );
                }
            });
        }
    });

    let m = svc.metrics();
    let total = N_CLIENTS * N_REQUESTS;
    // no lost or duplicated requests: the service counted exactly what
    // the clients received, and batching never exceeded its bound
    assert_eq!(m.requests, total);
    assert!(m.max_batch_seen >= 1 && m.max_batch_seen <= MAX_BATCH, "{}", m.max_batch_seen);
    assert!(
        m.batches >= total.div_ceil(MAX_BATCH) && m.batches <= total,
        "batches {} outside [{}, {total}]",
        m.batches,
        total.div_ceil(MAX_BATCH)
    );
    // one latency sample per answered request, none negative
    assert_eq!(m.latency.count(), total as u64);
    assert!(m.latency.quantile(0.5) > 0.0);
    assert!(m.latency.quantile(0.99) >= m.latency.quantile(0.5));
    assert!(m.batch_secs_total > 0.0);
}

#[test]
fn mixed_good_and_bad_requests_never_poison_the_batch_loop() {
    // concurrent clients where every other request has the wrong
    // dimension: the bad ones error at the client, the good ones are
    // answered correctly, and the shared loop survives it all
    let (spec, model, x, _) = trained(64);
    let z = spec.build().featurize(&x);
    let expect = model.predict(&z);
    let svc =
        PredictionService::start(spec, model, 8, Duration::ZERO).expect("start service");
    std::thread::scope(|scope| {
        for t in 0..4usize {
            let client = svc.client();
            let x = &x;
            let expect = &expect;
            scope.spawn(move || {
                for r in 0..32usize {
                    let i = (t * 32 + r) % x.rows();
                    if r % 2 == 0 {
                        let got = client.predict(x.row(i)).expect("served");
                        assert_eq!(got.to_bits(), expect[i].to_bits());
                    } else {
                        let wrong = vec![0.0; 2 + (r % 3) * 2]; // 2, 4 or 6 values, never 3
                        let err = client.predict_vec(&wrong).unwrap_err();
                        assert!(err.contains("expects d = 3"), "{err}");
                    }
                }
            });
        }
    });
    // only the well-formed half was ever admitted
    assert_eq!(svc.metrics().requests, 4 * 16);
}

#[test]
fn shutdown_after_concurrency_reports_final_metrics() {
    let (spec, model, x, _) = trained(32);
    let svc =
        PredictionService::start(spec, model, 4, Duration::ZERO).expect("start service");
    std::thread::scope(|scope| {
        for t in 0..4usize {
            let client = svc.client();
            let x = &x;
            scope.spawn(move || {
                for r in 0..8usize {
                    client.predict(x.row((t * 8 + r) % x.rows())).expect("served");
                }
            });
        }
    });
    let m = svc.shutdown();
    assert_eq!(m.requests, 32);
    assert_eq!(m.latency.count(), 32);
}
