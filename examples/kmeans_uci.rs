//! Table-3-style workload: kernel k-means on the UCI-geometry clustering
//! datasets via random Gegenbauer features.
//!
//! Run: cargo run --release --example kmeans_uci [-- --dataset abalone --m 512]

use gzk::cli::Args;
use gzk::data::{clustering_dataset, CLUSTERING_SPECS};
use gzk::features::{Featurizer, GegenbauerFeatures, RadialTable};
use gzk::kmeans::{greedy_accuracy, kmeans};

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    let name = args.get("dataset").unwrap_or("abalone").to_string();
    let m = args.get_usize("m", 512);
    let scale = args.get_f64("scale", 0.25);
    let seed = args.get_u64("seed", 1);

    let spec = *CLUSTERING_SPECS
        .iter()
        .find(|s| s.name == name)
        .unwrap_or_else(|| panic!("unknown dataset {name}; options: {:?}",
            CLUSTERING_SPECS.iter().map(|s| s.name).collect::<Vec<_>>()));
    let scaled = gzk::data::ClusteringSpec {
        name: spec.name,
        n: ((spec.n as f64 * scale) as usize).max(50 * spec.k),
        d: spec.d,
        k: spec.k,
    };
    println!("== kernel k-means on {} (n={}, d={}, k={}) ==", spec.name, scaled.n, spec.d, spec.k);
    let ds = clustering_dataset(scaled, seed);

    let s = if spec.d > 16 { 1 } else { 2 };
    let q = (spec.d / 2 + 6).min(12);
    let feat = GegenbauerFeatures::new(RadialTable::gaussian(spec.d, q, s), m / s, seed);
    let t0 = std::time::Instant::now();
    let z = feat.featurize(&ds.x);
    println!("featurized in {:.2}s -> Z {}x{}", t0.elapsed().as_secs_f64(), z.rows(), z.cols());

    let res = kmeans(&z, spec.k, 50, seed);
    println!(
        "k-means objective {:.4} after {} Lloyd iterations",
        res.objective, res.iterations
    );
    let acc = greedy_accuracy(&res.assignments, &ds.labels, spec.k);
    println!("greedy label accuracy vs generator ground truth: {:.1}%", 100.0 * acc);
}
