//! END-TO-END DRIVER (DESIGN.md §4): exercises every layer of the system on
//! a real small workload —
//!
//!   1. synthetic Earth-elevation dataset (S^2 regression);
//!   2. one-round distributed featurization + KRR across worker threads,
//!      featurizing through the AOT jax/Pallas PJRT executables when the
//!      artifacts are present (falling back to the native path otherwise);
//!   3. single-pass STREAMING ingestion of a second data wave;
//!   4. batched prediction serving with latency/throughput reporting.
//!
//! Run: make e2e   (or: cargo run --release --example streaming_service)
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use gzk::cli::Args;
use gzk::coordinator::{
    fit_one_round, Backend, Family, FeatureSpec, PredictionService, StreamBatch, StreamingKrr,
};
use gzk::data;
use gzk::krr::mse;
use gzk::runtime::default_artifact_dir;
use std::time::{Duration, Instant};

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    let n = args.get_usize("n", 30_000);
    let m = args.get_usize("m", 512);
    let n_workers = args.get_usize("workers", 4);
    let n_requests = args.get_usize("requests", 4_000);
    let seed = args.get_u64("seed", 1);

    println!("=== gzk end-to-end: distributed train -> stream -> serve ===\n");

    // ---- data -----------------------------------------------------------
    let ds = data::elevation(n, seed);
    let (x_tr, y_tr, x_te, y_te) = data::split(&ds.x, &ds.y, 0.1, seed);
    println!("[data] elevation: {} train / {} test points on S^2", x_tr.rows(), x_te.rows());

    let spec = FeatureSpec {
        family: Family::Gaussian { bandwidth: 1.0 },
        d: 3,
        q: 12,
        s: 2,
        m: m / 2,
        seed,
    };

    // ---- phase 1: one-round distributed fit (PJRT backend if available) --
    let artifact_dir = default_artifact_dir();
    let have_artifacts = artifact_dir.join("manifest.json").exists();
    let backend = if have_artifacts && !args.has("native") {
        println!("[train] using PJRT backend (AOT jax/Pallas artifacts at {artifact_dir:?})");
        Backend::Pjrt { artifact_dir }
    } else {
        println!("[train] using native backend (no artifacts found — run `make artifacts`)");
        Backend::Native
    };
    let half = x_tr.rows() / 2;
    let x_wave1 = x_tr.row_block(0, half);
    let y_wave1 = &y_tr[..half];
    let t0 = Instant::now();
    let fit = fit_one_round(&spec, &x_wave1, y_wave1, 1e-2, n_workers, 2048, backend);
    println!(
        "[train] one-round fit: {} rows, {} shards, {} workers, wall {:.2}s (featurize CPU {:.2}s)",
        fit.stats.n,
        fit.n_shards,
        fit.n_workers,
        t0.elapsed().as_secs_f64(),
        fit.featurize_secs_total,
    );

    // ---- phase 2: stream the second wave into the same sufficient stats --
    let stream = StreamingKrr::start(spec.clone(), 4);
    let t1 = Instant::now();
    for lo in (half..x_tr.rows()).step_by(1024) {
        let hi = (lo + 1024).min(x_tr.rows());
        stream
            .handle()
            .push(StreamBatch { x: x_tr.row_block(lo, hi), y: y_tr[lo..hi].to_vec() })
            .expect("stream open");
    }
    let (_, wave2_stats) = stream.finalize(1e-2);
    println!(
        "[stream] single-pass ingested {} more rows in {:.2}s (O(F^2) memory)",
        wave2_stats.n,
        t1.elapsed().as_secs_f64()
    );

    // merge both waves and resolve
    let mut all_stats = fit.stats;
    all_stats.merge(&wave2_stats);
    let lam = 1e-2 * all_stats.n as f64 / 1000.0;
    let model = all_stats.solve(lam);
    println!("[train] merged model over {} rows (lambda {lam:.3})", all_stats.n);

    // ---- phase 2b: streaming k-means over the same feature stream --------
    let feat = spec.build();
    let mut skm = gzk::kmeans::StreamingKmeans::new(6, spec.feature_dim());
    let t_km = Instant::now();
    for lo in (0..x_tr.rows().min(8192)).step_by(1024) {
        let hi = (lo + 1024).min(x_tr.rows());
        use gzk::features::Featurizer;
        skm.absorb(&feat.featurize(&x_tr.row_block(lo, hi)));
    }
    {
        use gzk::features::Featurizer;
        let z_probe = feat.featurize(&x_te.row_block(0, x_te.rows().min(1024)));
        println!(
            "[stream] mini-batch kernel k-means (k=6) over the same stream: objective {:.4} in {:.2}s",
            skm.objective(&z_probe),
            t_km.elapsed().as_secs_f64()
        );
    }

    // ---- phase 3: serve -------------------------------------------------
    let svc = PredictionService::start(spec.clone(), model, 64, Duration::ZERO);
    let client = svc.client();
    let _ = client.predict(x_te.row(0)); // warmup
    let mut latencies = Vec::with_capacity(n_requests);
    let mut preds = Vec::with_capacity(n_requests);
    let t2 = Instant::now();
    for r in 0..n_requests {
        let i = r % x_te.rows();
        let t = Instant::now();
        preds.push(client.predict(x_te.row(i)).expect("served"));
        latencies.push(t.elapsed().as_secs_f64());
    }
    let wall = t2.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let truth: Vec<f64> = (0..n_requests).map(|r| y_te[r % y_te.len()]).collect();
    let metrics = svc.metrics();

    println!(
        "[serve] {} requests in {:.2}s -> {:.0} req/s; p50 {:.1}us p99 {:.1}us; {} batches (max {})",
        n_requests,
        wall,
        n_requests as f64 / wall,
        latencies[n_requests / 2] * 1e6,
        latencies[n_requests * 99 / 100] * 1e6,
        metrics.batches,
        metrics.max_batch_seen
    );
    let test_mse = mse(&preds, &truth);
    println!("[serve] test MSE over served predictions: {test_mse:.4}");

    // target variance as the trivial baseline — the model must beat it
    let ybar = y_te.iter().sum::<f64>() / y_te.len() as f64;
    let var = y_te.iter().map(|v| (v - ybar) * (v - ybar)).sum::<f64>() / y_te.len() as f64;
    println!("[serve] baseline (predict mean) MSE: {var:.4}");
    assert!(test_mse < 0.5 * var, "model must clearly beat the mean baseline");
    println!("\nend-to-end OK");
}
