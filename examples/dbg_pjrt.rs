use anyhow::Result;
fn main() -> Result<()> {
    let client = xla::PjRtClient::cpu()?;
    for name in ["r_only", "pow_only", "pallas_only"] {
        let proto = xla::HloModuleProto::from_text_file(&format!("/tmp/bisect_{name}.hlo.txt"))?;
        let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;
        let x: Vec<f32> = (0..24).map(|i| ((i%5) as f32 - 2.0)*0.3).collect();
        let mut w = vec![0.0f32; 12];
        for i in 0..4 { w[i*3] = 1.0; }
        let xl = xla::Literal::vec1(&x).reshape(&[8, 3])?;
        let wl = xla::Literal::vec1(&w).reshape(&[4, 3])?;
        let out = exe.execute::<xla::Literal>(&[xl, wl])?[0][0].to_literal_sync()?;
        let v = out.to_tuple1()?.to_vec::<f32>()?;
        let nz = v.iter().filter(|&&a| a != 0.0).count();
        println!("{name}: nonzero {}/{} first6 {:?}", nz, v.len(), &v[..6]);
    }
    Ok(())
}
