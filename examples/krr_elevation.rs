//! Table-2-style workload: Gaussian-kernel ridge regression on the
//! synthetic Earth-elevation dataset (points on S^2), comparing the
//! paper's Gegenbauer features against Fourier features and Nystrom.
//!
//! Run: cargo run --release --example krr_elevation [-- --n 20000 --m 1024]

use gzk::cli::Args;
use gzk::data;
use gzk::experiments::table2::median_bandwidth;
use gzk::features::{Featurizer, FourierFeatures, GegenbauerFeatures, NystromFeatures, RadialTable};
use gzk::kernels::Kernel;
use gzk::krr::{mse, FeatureRidge};
use std::time::Instant;

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    let n = args.get_usize("n", 20_000);
    let m = args.get_usize("m", 1024);
    let seed = args.get_u64("seed", 1);

    println!("== elevation KRR: n={n}, m={m} ==");
    let ds = data::elevation(n, seed);
    let (x_tr, y_tr, x_te, y_te) = data::split(&ds.x, &ds.y, 0.1, seed);
    let bw = median_bandwidth(&x_tr, seed);
    println!("median-heuristic bandwidth: {bw:.3}");

    // Gegenbauer: scale inputs by 1/bw, unit-bandwidth GZK table
    let mut x_tr_s = x_tr.clone();
    x_tr_s.scale(1.0 / bw);
    let mut x_te_s = x_te.clone();
    x_te_s.scale(1.0 / bw);
    let s = 2;
    let table = RadialTable::gaussian(3, 12, s);

    let lam = 1e-2 * x_tr.rows() as f64 / 1000.0;
    for method in ["gegenbauer", "fourier", "nystrom"] {
        let t0 = Instant::now();
        let (z_tr, z_te) = match method {
            "gegenbauer" => {
                let f = GegenbauerFeatures::new(table.clone(), m / s, seed + 1);
                (f.featurize(&x_tr_s), f.featurize(&x_te_s))
            }
            "fourier" => {
                let f = FourierFeatures::new(3, m, bw, seed + 2);
                (f.featurize(&x_tr), f.featurize(&x_te))
            }
            _ => {
                let f = NystromFeatures::fit(
                    Kernel::Gaussian { bandwidth: bw },
                    &x_tr,
                    m,
                    1e-3,
                    seed + 3,
                );
                (f.featurize(&x_tr), f.featurize(&x_te))
            }
        };
        let feat_secs = t0.elapsed().as_secs_f64();
        let model = FeatureRidge::fit(&z_tr, &y_tr, lam);
        let err = mse(&model.predict(&z_te), &y_te);
        println!("{method:>11}: test MSE {err:.4}   featurize {feat_secs:.2}s");
    }
}
