//! Quickstart: approximate a Gaussian kernel with random Gegenbauer
//! features, fit ridge regression, and verify against the exact kernel.
//!
//! Run: cargo run --release --example quickstart

use gzk::features::{Featurizer, GegenbauerFeatures, RadialTable};
use gzk::kernels::Kernel;
use gzk::krr::{mse, ExactKrr, FeatureRidge};
use gzk::linalg::Mat;
use gzk::rng::Rng;
use gzk::spectral::spectral_epsilon;

fn main() {
    // 1. a toy dataset: y = sin(2 x0) + x1 * x2 + noise
    let mut rng = Rng::new(7);
    let n = 400;
    let x = Mat::from_fn(n, 3, |_, _| rng.normal() * 0.6);
    let y: Vec<f64> =
        (0..n).map(|i| (2.0 * x[(i, 0)]).sin() + x[(i, 1)] * x[(i, 2)] + 0.05 * rng.normal()).collect();

    // 2. the paper's feature map: Gaussian kernel as a GZK, truncated at
    //    (q, s), m random directions on S^2
    // points here have norms up to ~2, so keep enough radial channels
    // (s) for the Gaussian GZK truncation to stay unbiased (Thm 12)
    let table = RadialTable::gaussian(/*d=*/ 3, /*q=*/ 14, /*s=*/ 5);
    let feat = GegenbauerFeatures::new(table, /*m=*/ 1024, /*seed=*/ 42);
    let z = feat.featurize(&x);
    println!("featurized {} points -> Z is {}x{}", n, z.rows(), z.cols());

    // 3. how good is the kernel approximation? (Eq. 1)
    let k = Kernel::Gaussian { bandwidth: 1.0 }.gram(&x);
    let eps = spectral_epsilon(&k, &z.matmul_nt(&z), 0.1);
    println!("(eps, lambda=0.1)-spectral approximation: eps = {eps:.3}");

    // 4. ridge regression in feature space vs the exact kernel solver
    let lam = 1e-2;
    let model = FeatureRidge::fit(&z, &y, lam);
    let exact = ExactKrr::fit(Kernel::Gaussian { bandwidth: 1.0 }, x.clone(), &y, lam);

    let x_test = Mat::from_fn(100, 3, |_, _| rng.normal() * 0.6);
    let y_test: Vec<f64> = (0..100)
        .map(|i| (2.0 * x_test[(i, 0)]).sin() + x_test[(i, 1)] * x_test[(i, 2)])
        .collect();
    let z_test = feat.featurize(&x_test);
    let mse_feat = mse(&model.predict(&z_test), &y_test);
    let mse_exact = mse(&exact.predict(&x_test), &y_test);
    println!("test MSE: gegenbauer features {mse_feat:.4} vs exact KRR {mse_exact:.4}");
    assert!(mse_feat < 2.0 * mse_exact + 0.01, "features should track the exact solver");
    println!("quickstart OK");
}
